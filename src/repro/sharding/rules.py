"""Partition rules: parameter/optimizer/cache/batch PartitionSpecs.

Layout (DESIGN.md §6):
* ``model`` axis — tensor parallel: attention heads, FFN hidden, experts,
  vocab.
* ``data`` axes (("pod","data") or ("data",)) — batch parallel; parameters
  are *additionally* sharded over the data axes on their non-model dim
  (FSDP/ZeRO-style), which is what lets 20B–398B × Adam fit per chip.
* Norm scales and other small vectors are replicated.

Rules match on parameter path suffixes produced by the model's naming
convention; stacked scan groups contribute a leading ``num_groups`` dim
which is never sharded.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.utils import tree_map_with_path


@dataclass(frozen=True)
class MeshAxes:
    data: tuple            # ("pod", "data") or ("data",)
    model: str             # "model"

    @property
    def all_data(self):
        return self.data if len(self.data) > 1 else self.data[0]


# (path-suffix, spec-builder) rules; first match wins.  Specs are for the
# *unstacked* param; a leading None is prepended for scan-group stacking.
def _rules(ax: MeshAxes):
    D, M = ax.all_data, ax.model
    return [
        ("embed/embedding", P(M, D)),
        ("lm_head/w", P(D, M)),
        ("enc_head/w", P(D, M)),
        ("frontend_proj/w", P(None, M)),
        ("mask_embed", P()),
        # attention + mlstm projections
        ("wq/w", P(D, M)), ("wk/w", P(D, M)), ("wv/w", P(D, M)),
        ("wq/b", P(M)), ("wk/b", P(M)), ("wv/b", P(M)),
        ("wo/w", P(M, D)),
        # mlp
        ("w_up/w", P(D, M)), ("w_gate/w", P(D, M)), ("w_down/w", P(M, D)),
        ("mlp/w_up", P(D, M)), ("mlp/w_gate", P(D, M)), ("mlp/w_down", P(M, D)),
        # moe
        ("w_router", P(D, None)),
        ("experts_up", P(M, D, None)),
        ("experts_gate", P(M, D, None)),
        ("experts_down", P(M, None, D)),
        # mamba
        ("in_proj/w", P(D, M)),
        ("conv_w", P(None, M)), ("conv_b", P(M)),
        ("x_proj/w", P(M, None)),
        ("dt_proj/w", P(None, M)), ("dt_proj/b", P(M)),
        ("A_log", P(M, None)), ("D", P(M)),
        ("out_proj/w", P(M, D)),
        # xlstm
        ("w_igate/w", P(D, None)), ("w_igate/b", P()),
        ("w_fgate/w", P(D, None)), ("w_fgate/b", P()),
        ("w_x/w", P(D, M)), ("w_r", P()),
        ("up_proj/w", P(D, M)), ("down_proj/w", P(M, D)),
        # norms / scalars (must come after the specific rules)
        ("scale", P()), ("bias", P()), ("/b", P()),
    ]


def _serve2d_rules(ax: MeshAxes):
    """Serving layout (§Perf): weights sharded on their OUTPUT dim over the
    *combined* (data × model) device set — decode then all-gathers
    activation-sized tensors per step instead of parameter-sized FSDP
    gathers.  MoE expert slabs keep the train layout (the shard_map EP path
    pins experts to the model axis)."""
    D, M = ax.all_data, ax.model
    DM = (tuple(ax.data) + (M,)) if isinstance(D, tuple) else (D, M)
    return [
        ("embed/embedding", P(DM, None)),
        ("lm_head/w", P(None, DM)),
        ("enc_head/w", P(None, DM)),
        ("wq/w", P(None, DM)), ("wk/w", P(None, DM)), ("wv/w", P(None, DM)),
        ("wq/b", P(DM)), ("wk/b", P(DM)), ("wv/b", P(DM)),
        ("wo/w", P(DM, None)),
        ("mlp/w_up", P(None, DM)), ("mlp/w_gate", P(None, DM)),
        ("mlp/w_down", P(DM, None)),
        ("in_proj/w", P(None, DM)),
        ("conv_w", P(None, DM)), ("conv_b", P(DM)),
        ("x_proj/w", P(DM, None)),
        ("dt_proj/w", P(None, DM)), ("dt_proj/b", P(DM)),
        ("A_log", P(DM, None)), ("D", P(DM)),
        ("out_proj/w", P(DM, None)),
        ("w_x/w", P(None, DM)),
        ("up_proj/w", P(None, DM)), ("down_proj/w", P(DM, None)),
    ]


def _shard_count(entry, ax: MeshAxes) -> int:
    if entry is None:
        return 1
    sizes = {"model": 16, "data": 16, "pod": 2}
    names = entry if isinstance(entry, tuple) else (entry,)
    return int(np.prod([sizes.get(n, 1) for n in names]))


def _spec_for(path: str, shape, ax: MeshAxes, mode: str = "train"):
    ndim = len(shape)
    stacked = path.startswith("groups/")
    base_ndim = ndim - 1 if stacked else ndim
    base_shape = shape[1:] if stacked else shape

    def resolve(rules):
        for suffix, spec in rules:
            if path.endswith(suffix):
                s = tuple(spec)
                if len(s) < base_ndim:
                    s = s + (None,) * (base_ndim - len(s))
                s = s[:base_ndim]
                return s
        return None

    spec = None
    if mode == "serve2d":
        s = resolve(_serve2d_rules(ax))
        if s is not None and all(
                dim % _shard_count(e, ax) == 0
                for dim, e in zip(base_shape, s)):
            spec = s
    if spec is None:
        spec = resolve(_rules(ax)) or ()
    if mode == "serve1d":
        # serving: drop the FSDP (data-axis) factors — weights live sharded
        # over `model` only, so decode never all-gathers parameters.
        def strip(e):
            if e is None:
                return None
            names = e if isinstance(e, tuple) else (e,)
            kept = tuple(n for n in names if n == ax.model)
            return kept[0] if len(kept) == 1 else (kept or None)
        spec = tuple(strip(e) for e in spec)
    if stacked:
        spec = (None,) + tuple(spec)
    return P(*spec)


def param_specs(params, ax: MeshAxes, mode: str = "train"):
    """PartitionSpec pytree mirroring ``params`` (works on SDS trees).

    mode="train": TP over model axis + FSDP over data axes.
    mode="serve2d": output-dim sharding over all devices (decode layout)."""
    return tree_map_with_path(
        lambda p, leaf: _spec_for(p, leaf.shape, ax, mode), params)


def batch_specs(cfg: ModelConfig, shape: InputShape, ax: MeshAxes, batch_sharded: bool):
    """Specs for the input batch pytree of a train/prefill step."""
    bdim = ax.all_data if batch_sharded else None
    if cfg.frontend == "token":
        return {"tokens": P(bdim, None)}
    if cfg.frontend == "vision_patches":
        return {"patches": P(bdim, None, None), "tokens": P(bdim, None)}
    if cfg.frontend == "audio_frames":
        return {"frames": P(bdim, None, None), "mask": P(bdim, None),
                "labels": P(bdim, None)}
    raise ValueError(cfg.frontend)


def cache_specs(cfg: ModelConfig, shape: InputShape, ax: MeshAxes,
                batch_sharded: bool, caches_sds):
    """Specs for the decode cache pytree (stacked leading group dim).

    * batch shardable (decode_32k): batch → data axes, KV seq → model.
    * batch=1 (long_500k): KV seq → (data, model) — context parallel;
      recurrent-state channel dims → model.
    """
    from repro.models.attention import KVCache
    from repro.models.ssm import MambaState
    from repro.models.xlstm import MLSTMState, SLSTMState

    D, M = ax.all_data, ax.model
    bdim = D if batch_sharded else None
    seq_dims = M if batch_sharded else (D, M) if isinstance(D, str) else (*ax.data, M)

    def spec_tree(cache):
        if isinstance(cache, KVCache):
            s = P(None, bdim, seq_dims, None, None)
            return KVCache(s, s)
        if isinstance(cache, MambaState):
            return MambaState(P(None, bdim, M, None), P(None, bdim, None, M))
        if isinstance(cache, MLSTMState):
            return MLSTMState(P(None, bdim, None, None, None),
                              P(None, bdim, None, None), P(None, bdim, None),
                              P(None, bdim, None, M))
        if isinstance(cache, SLSTMState):
            s = P(None, bdim, None)
            return SLSTMState(s, s, s, s)
        raise TypeError(type(cache))

    return {k: spec_tree(v) for k, v in caches_sds.items()}


def wave_window_specs(ax: MeshAxes) -> dict:
    """Specs for one HOST WINDOW of a placed synthesis wave (the
    multi-host serving path — ``serve/topology.py``).

    The window's image-shaped tensors (x / ε / noise, batch-leading 4-D)
    and its conditioning rows shard their batch dim over the host's data
    axes — a window is granule-rounded so this always divides — while the
    wave-resident scalar table (the (4, B_wave) per-row ᾱ_t/ᾱ_prev/s/
    active stack) and the wave-wide guidance vector are REPLICATED: every
    device reads its rows' scalar slots through the ``cfg_fuse``
    ``row_offset`` indexing instead of resharding a sliced copy of the
    table per host per step.

    MIXED-guidance waves add three operands: the per-row ``mode`` vector
    is wave-resident (read through the same ``row_offset`` indexing, so
    it replicates like the scalar table), while the classifier ids and
    labels are window-local row vectors that shard with the window's
    batch dim like ``row_keys``."""
    D = ax.all_data
    return {
        "window": P(D, None, None, None),    # x / eps_c / eps_u / noise
        "cond": P(D, None),                  # window conditioning rows
        "row_keys": P(D),                    # per-row noise keys
        "scalar_table": P(None, None),       # wave-resident (4, B_wave)
        "guidance": P(None),                 # wave-wide (B_wave,)
        "mode": P(None),                     # wave-wide (B_wave,) modes
        "clf_ids": P(D),                     # window-local ensemble slots
        "labels": P(D),                      # window-local clf targets
    }


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
