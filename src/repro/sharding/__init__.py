from repro.sharding.rules import (MeshAxes, batch_specs, cache_specs,
                                  param_specs, to_shardings)
