"""Checkpointing: pytree <-> .npz + JSON manifest (host-gathered).

Flat keys are the ``tree_paths`` path strings, so checkpoints are stable
across refactors that keep parameter names, and are inspectable with
plain numpy.  Used for the frozen DM cache and trained global models.

Dtypes round-trip faithfully: extension dtypes numpy's npz format cannot
represent (bfloat16, float8 — they pickle to opaque void records) are
stored as raw bit patterns in a same-width unsigned integer array and
re-viewed on load; every leaf's dtype is recorded in the JSON manifest
and validated against the npz contents when restoring.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from repro.utils import tree_paths

# numpy's own format handles these; anything else (ml_dtypes extension
# types) goes through the raw-bits path
_NATIVE_KINDS = frozenset("biufc")


def _to_native(a: np.ndarray) -> np.ndarray:
    if a.dtype.kind in _NATIVE_KINDS:
        return a
    return a.view(np.dtype(f"u{a.dtype.itemsize}"))


def save_pytree(tree, path: str | Path, meta: dict | None = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = tree_paths(tree)
    arrays, dtypes = {}, {}
    for p, l in flat:
        a = np.asarray(l)
        dtypes[p] = str(a.dtype)
        arrays[p] = _to_native(a)
    np.savez(path.with_suffix(".npz"), **arrays)
    manifest = {"keys": [p for p, _ in flat], "dtypes": dtypes,
                "meta": meta or {}}
    path.with_suffix(".json").write_text(json.dumps(manifest, indent=1))


def load_pytree(template, path: str | Path):
    """Restore into the structure of ``template`` (values replaced).

    Leaves come back with their SAVED dtype (recorded in the manifest),
    not the template's — a bf16 checkpoint restores as bf16 even into an
    f32 template.  Pre-dtype-manifest checkpoints restore with whatever
    dtype the npz holds, as before.
    """
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    manifest = json.loads(path.with_suffix(".json").read_text())
    dtypes = manifest.get("dtypes", {})
    flat = tree_paths(template)
    leaves = []
    for p, leaf in flat:
        if p not in data:
            raise KeyError(f"checkpoint missing key {p}")
        arr = data[p]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{p}: shape {arr.shape} != {leaf.shape}")
        if p in dtypes:
            want = jax.numpy.dtype(dtypes[p])
            if arr.dtype.kind in _NATIVE_KINDS and arr.dtype == want:
                pass                              # stored directly
            elif (want.kind not in _NATIVE_KINDS
                  and arr.dtype == np.dtype(f"u{want.itemsize}")):
                arr = arr.view(want)              # raw-bits extension dtype
            else:
                raise ValueError(
                    f"{p}: npz dtype {arr.dtype} inconsistent with manifest "
                    f"dtype {dtypes[p]}")
        leaves.append(jax.numpy.asarray(arr))
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, leaves)


def exists(path: str | Path) -> bool:
    path = Path(path)
    return path.with_suffix(".npz").exists() and path.with_suffix(".json").exists()
