"""Checkpointing: pytree <-> .npz + JSON manifest (host-gathered).

Flat keys are the ``tree_paths`` path strings, so checkpoints are stable
across refactors that keep parameter names, and are inspectable with
plain numpy.  Used for the frozen DM cache and trained global models.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from repro.utils import tree_paths


def save_pytree(tree, path: str | Path, meta: dict | None = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = tree_paths(tree)
    arrays = {p: np.asarray(l) for p, l in flat}
    np.savez(path.with_suffix(".npz"), **arrays)
    manifest = {"keys": [p for p, _ in flat], "meta": meta or {}}
    path.with_suffix(".json").write_text(json.dumps(manifest, indent=1))


def load_pytree(template, path: str | Path):
    """Restore into the structure of ``template`` (values replaced)."""
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    flat = tree_paths(template)
    leaves = []
    for p, leaf in flat:
        if p not in data:
            raise KeyError(f"checkpoint missing key {p}")
        arr = data[p]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{p}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr))
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, leaves)


def exists(path: str | Path) -> bool:
    path = Path(path)
    return path.with_suffix(".npz").exists() and path.with_suffix(".json").exists()
