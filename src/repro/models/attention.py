"""Multi-head attention with the zoo's variants.

Supports: GQA/MQA (num_kv_heads <= num_heads), QKV bias (qwen2), qk-norm
(qwen3/olmoe), attention-logit softcap (gemma2), sliding-window masks
(gemma2 local layers), bidirectional encoder mode (hubert), KV-cache decode.

The matmul path can be routed through the Pallas flash-attention kernel
(``repro.kernels.flash_attention``) via ``use_pallas=True``; the jnp path
below is the reference used for CPU smoke tests and as the kernel oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense, init_dense, init_rmsnorm, rmsnorm
from repro.utils import softcap as _softcap


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, n_kv, head_dim)
    v: jax.Array  # (B, S_max, n_kv, head_dim)


def init_attention(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_dense(ks[0], d, cfg.num_heads * hd, bias=cfg.qkv_bias),
        "wk": init_dense(ks[1], d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": init_dense(ks[2], d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": init_dense(ks[3], cfg.num_heads * hd, d, bias=False),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(ks[4], hd)
        p["k_norm"] = init_rmsnorm(ks[5], hd)
    return p


def _project_qkv(params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = dense(params["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = dense(params["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = dense(params["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend(q, k, v, mask, cfg: ModelConfig, window: int):
    """Reference attention.  q: (B,Sq,Hq,hd); k,v: (B,Sk,Hkv,hd).

    ``mask``: (B, Sq, Sk) or (Sq, Sk) boolean, True = attend.
    """
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    scale = hd ** -0.5
    # keep q/k in the storage dtype; accumulate the contraction in f32
    # (MXU-native: no full-cache f32 materialisation on the decode path)
    qs = (q * scale).reshape(B, Sq, Hkv, rep, hd)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qs, k,
                        preferred_element_type=jnp.float32)
    if cfg.attn_softcap:
        logits = _softcap(logits, cfg.attn_softcap)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None]
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype).reshape(B, Sq, Hq * hd)


def _attend_chunked(q, k, v, cfg: ModelConfig, *, causal: bool, window: int,
                    blk: int = 1024):
    """Flash-semantics attention in pure XLA (§Perf): lax.scan over KV
    blocks with online-softmax running stats.  Never materialises the
    (B,H,Sq,Sk) probability tensor — peak intermediate is (B,H,Sq,blk) and
    the per-block mask is computed from iotas (no (Sq,Sk) bool buffer).
    This is the XLA-level analogue of kernels/flash_attention (which is
    the TPU-native Pallas version of the same blocking)."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    blk = min(blk, Sk)
    assert Sk % blk == 0, (Sk, blk)
    nk = Sk // blk
    scale = hd ** -0.5
    qs = (q * scale).reshape(B, Sq, Hkv, rep, hd)
    kc = k.reshape(B, nk, blk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, blk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(Sq)

    def step(carry, inp):
        m, l, acc = carry
        j, kb, vb = inp
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qs, kb,
                       preferred_element_type=jnp.float32)
        if cfg.attn_softcap:
            s = _softcap(s, cfg.attn_softcap)
        kpos = j * blk + jnp.arange(blk)
        mask = jnp.ones((Sq, blk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhrqk,bkhd->bhrqd", p.astype(v.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, rep, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, rep, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nk), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq * hd)
    return out.astype(q.dtype)


def make_mask(Sq: int, Sk: int, *, causal: bool, window: int, q_offset: int = 0):
    """(Sq, Sk) boolean attention mask.  q position i maps to absolute
    position ``i + q_offset``; keys are absolute positions 0..Sk-1."""
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    return mask


def attention(params, cfg: ModelConfig, x, positions, *, kind: str = "attn",
              use_pallas: bool = False, impl: str = "naive", par=None):
    """Full-sequence attention (train / prefill).  Returns (out, (k, v))."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    kv_cache = (k, v)
    if par is not None and par.gqa_repeat:
        rep = cfg.num_heads // cfg.num_kv_heads
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
    if par is not None and par.qkv_spec is not None:
        q_sh, kv_sh = par.qkv_spec
        q = jax.lax.with_sharding_constraint(q, q_sh)
        k = jax.lax.with_sharding_constraint(k, q_sh if par.gqa_repeat else kv_sh)
        v = jax.lax.with_sharding_constraint(v, q_sh if par.gqa_repeat else kv_sh)
    window = cfg.sliding_window if kind == "attn_local" else 0
    causal = not cfg.is_encoder
    if use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(
            q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap)
        out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    elif impl == "chunked":
        out = _attend_chunked(q, k, v, cfg, causal=causal, window=window)
    else:
        mask = make_mask(S, S, causal=causal, window=window)
        out = _attend(q, k, v, mask, cfg, window)
    return dense(params["wo"], out), kv_cache


def attention_decode(params, cfg: ModelConfig, x, cache: KVCache, pos,
                     *, kind: str = "attn"):
    """Single-token decode.  x: (B, 1, d); pos: scalar int32 (same for the
    whole batch — standard synchronous decode).  Returns (out, new_cache)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), pos, axis=1)
    S_max = k.shape[1]
    window = cfg.sliding_window if kind == "attn_local" else 0
    kpos = jnp.arange(S_max)
    valid = kpos <= pos
    if window:
        valid &= kpos > pos - window
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, S_max))
    out = _attend(q, k, v, mask, cfg, window)
    return dense(params["wo"], out), KVCache(k, v)


def attention_decode_stacked(params, cfg: ModelConfig, x, cache: KVCache,
                             g, pos, *, kind: str = "attn"):
    """Single-token decode against the STACKED (num_groups-leading) cache:
    writes the new K/V token in place at [g, :, pos] — one token-sized DUS
    per layer instead of a group-sized scan-ys writeback (§Perf: decode
    cache traffic drops from O(cache) to O(token) per step)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    zero = jnp.zeros((), jnp.int32)
    start = (g, zero, pos, zero, zero)
    k_all = jax.lax.dynamic_update_slice(cache.k,
                                         k_new[None].astype(cache.k.dtype), start)
    v_all = jax.lax.dynamic_update_slice(cache.v,
                                         v_new[None].astype(cache.v.dtype), start)
    k = jax.lax.dynamic_index_in_dim(k_all, g, 0, keepdims=False)
    v = jax.lax.dynamic_index_in_dim(v_all, g, 0, keepdims=False)
    S_max = k.shape[1]
    window = cfg.sliding_window if kind == "attn_local" else 0
    kpos = jnp.arange(S_max)
    valid = kpos <= pos
    if window:
        valid &= kpos > pos - window
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, S_max))
    out = _attend(q, k, v, mask, cfg, window)
    return dense(params["wo"], out), KVCache(k_all, v_all)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
