"""Mixture-of-Experts FFN with expert parallelism.

Two execution paths, numerically equivalent (up to capacity drops):

* ``dense``     — every expert computed for every selected token via a
                  static loop; used on CPU for tiny smoke tests and as the
                  oracle for the EP path.
* ``ep``        — production path: ``jax.shard_map`` manual only over the
                  ``model`` mesh axis.  Experts are sharded over ``model``;
                  activations stay replicated across ``model`` (Megatron-TP
                  convention), so dispatch is a *local* capacity-gather per
                  expert shard followed by a single ``psum`` combine — the
                  same collective cost as a TP FFN, no all-to-all needed.
                  (See DESIGN.md §4; EXPERIMENTS.md §Perf evaluates a
                  reduce-scatter variant.)

Routing: softmax router, top-k, renormalised gates (Mixtral convention —
noted in DESIGN.md as a simplification for phi3.5's sparsemixer), plus the
standard switch-transformer load-balance auxiliary loss.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import lecun_init
from repro.utils import cdiv


@dataclass(frozen=True)
class Parallel:
    """How the model is laid out on the mesh (None axes = not sharded)."""
    model_axis: Optional[str] = None   # tensor/expert-parallel axis name
    data_axes: tuple = ()              # batch axes ("pod","data")
    mesh: object = None                # jax Mesh (static, not traced)
    use_pallas: bool = False           # route hot paths through Pallas kernels
    moe_combine: str = "psum"          # psum | reduce_scatter  (§Perf knob)
    batch_sharded: bool = True         # False when global_batch < data shards
    resid_spec: object = None          # PartitionSpec pinned on the residual
                                       # stream between groups (§Perf: Megatron
                                       # sequence parallelism)
    logits_spec: object = None         # PartitionSpec pinned on the LM logits
                                       # (vocab-parallel loss; avoids a full
                                       # (B,S,V) f32 materialisation)
    attn_impl: str = "naive"           # naive | chunked  (§Perf knob: the
                                       # chunked path never materialises the
                                       # (B,H,S,S) probability tensor)
    prefill_last_only: bool = False    # serving: readout last position only
    qkv_spec: object = None            # (q_sharding, kv_sharding) pinned on
                                       # the projected q/k/v — stops GSPMD
                                       # from sharding the KV sequence dim
                                       # (which costs probs-sized all-reduces)
    gqa_repeat: bool = False           # materialise repeated KV heads so the
                                       # head dim shards cleanly (§Perf)
    decode_cache: str = "scan_ys"      # scan_ys | carry — cache plumbing for
                                       # decode.  "carry" (in-place DUS into
                                       # the scan carry) was REFUTED on XLA:
                                       # the carry fails to alias and copies
                                       # the full cache per group (§Perf log)

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, fe, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "w_router": lecun_init(ks[0], (d, E)),
        "experts_up": lecun_init(ks[1], (E, d, fe)),
        "experts_down": lecun_init(ks[2], (E, fe, d), fan_in_axes=(1,)),
    }
    if cfg.gated_mlp:
        p["experts_gate"] = lecun_init(ks[3], (E, d, fe))
    return p


def _route(w_router, x_flat, m: MoEConfig):
    """Returns (gates (T,k), idx (T,k), aux_loss scalar)."""
    logits = (x_flat @ w_router.astype(x_flat.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gates, idx = jax.lax.top_k(probs, m.top_k)                  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    T = x_flat.shape[0]
    one_hot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32)  # (T,k,E)
    f = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)              # dispatch frac
    pmean = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(f * pmean)
    return gates, idx, aux


def _expert_ffn(xe, up, down, gate, act: str):
    actfn = jax.nn.silu if act == "silu" else (lambda v: jax.nn.gelu(v, approximate=True))
    h = xe @ up.astype(xe.dtype)
    if gate is not None:
        h = actfn(xe @ gate.astype(xe.dtype)) * h
    else:
        h = actfn(h)
    return h @ down.astype(xe.dtype)


def _local_expert_pass(params, cfg: ModelConfig, x_flat, e_start: int, E_loc: int,
                       capacity: int, gates, idx):
    """Gather→FFN→scatter for ``E_loc`` experts starting at global id
    ``e_start``.  Works on local (sharded) or global (dense) expert slabs —
    ``params`` expert arrays must have leading dim ``E_loc``."""
    m = cfg.moe
    T = x_flat.shape[0]
    # Pad x with a zero row; out-of-range gather indices point at it.
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, x_flat.shape[1]), x_flat.dtype)], 0)
    out = jnp.zeros((T, cfg.d_model), x_flat.dtype)
    for e_loc in range(E_loc):
        g = e_start + e_loc
        w_t = jnp.sum(jnp.where(idx == g, gates, 0.0), axis=-1)       # (T,)
        sel = w_t > 0
        # capacity-limited token indices for this expert (fill -> padded row)
        tok = jnp.nonzero(sel, size=capacity, fill_value=T)[0]        # (C,)
        xe = x_pad[tok]                                               # (C, d)
        gate_w = params.get("experts_gate")
        h = _expert_ffn(xe, params["experts_up"][e_loc],
                        params["experts_down"][e_loc],
                        None if gate_w is None else gate_w[e_loc],
                        cfg.mlp_act)
        h = h * w_t[tok][:, None].astype(h.dtype)
        out = out.at[tok].add(h, mode="drop")
    return out


def moe_dense(params, cfg: ModelConfig, x):
    """Single-device reference path (all experts local)."""
    m = cfg.moe
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    gates, idx, aux = _route(params["w_router"], x_flat, m)
    T = x_flat.shape[0]
    capacity = max(1, cdiv(T * m.top_k, m.num_experts) * 4)  # generous: no drops
    out = _local_expert_pass(params, cfg, x_flat, 0, m.num_experts,
                             capacity, gates, idx)
    return out.reshape(B, S, d), aux


def moe_ep(params, cfg: ModelConfig, x, par: Parallel, batch_sharded: bool = True):
    """Expert-parallel path: fully-manual shard_map over all mesh axes.

    Experts shard over ``model``; tokens shard over the data axes (or are
    replicated when the batch is unshardable, e.g. batch=1 decode).  The
    only combine collective is a psum (or reduce-scatter + all-gather,
    §Perf knob) over ``model``.
    """
    m = cfg.moe
    M = par.model_size
    E_loc = m.num_experts // M
    d = cfg.d_model
    gated = "experts_gate" in params
    all_axes = set(par.mesh.axis_names)
    x_spec = P(par.data_axes) if (batch_sharded and par.data_axes) else P()

    def body(*args):
        w_router, e_up, e_down = args[:3]
        e_gate = args[3] if gated else None
        x_loc = args[-1]
        Bl, Sl, _ = x_loc.shape
        x_flat = x_loc.reshape(Bl * Sl, d)
        gates, idx, aux = _route(w_router, x_flat, m)
        T = x_flat.shape[0]
        capacity = max(1, int(T * m.top_k / m.num_experts * m.capacity_factor))
        e_start = jax.lax.axis_index(par.model_axis) * E_loc
        p_loc = {"experts_up": e_up, "experts_down": e_down}
        if gated:
            p_loc["experts_gate"] = e_gate
        out = _local_expert_pass(p_loc, cfg, x_flat, e_start, E_loc,
                                 capacity, gates, idx)
        if par.moe_combine == "reduce_scatter":
            # reduce-scatter over the token axis, then all-gather: same
            # bytes-on-wire as all-reduce but exposes overlap (§Perf).
            out = jax.lax.psum_scatter(out, par.model_axis, scatter_dimension=0,
                                       tiled=True)
            out = jax.lax.all_gather(out, par.model_axis, axis=0, tiled=True)
        else:
            out = jax.lax.psum(out, par.model_axis)
        if par.data_axes:
            aux = jax.lax.pmean(aux, par.data_axes)
        return out.reshape(Bl, Sl, d), aux

    args = [params["w_router"], params["experts_up"], params["experts_down"]]
    specs = [P(), P(par.model_axis), P(par.model_axis)]
    if gated:
        args.append(params["experts_gate"])
        specs.append(P(par.model_axis))
    args.append(x)
    specs.append(x_spec)
    # reduce_scatter+all_gather leaves values replicated over `model` but
    # the VMA checker cannot infer that statically — disable the check for
    # that combine mode only.
    check = par.moe_combine != "reduce_scatter"
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(body, mesh=par.mesh, axis_names=all_axes,
                           in_specs=tuple(specs), out_specs=(x_spec, P()),
                           check_vma=check)
    else:
        # jax < 0.5: experimental API; all mesh axes are manual (== the
        # all_axes set above) and the VMA checker is called check_rep
        from jax.experimental.shard_map import shard_map
        fn = shard_map(body, mesh=par.mesh, in_specs=tuple(specs),
                       out_specs=(x_spec, P()), check_rep=check)
    return fn(*args)


def moe_apply(params, cfg: ModelConfig, x, par: Parallel):
    """Dispatch to the EP or dense path.  Returns (out, aux_loss)."""
    if par.model_axis is not None and par.mesh is not None:
        return moe_ep(params, cfg, x, par, batch_sharded=par.batch_sharded)
    return moe_dense(params, cfg, x)
