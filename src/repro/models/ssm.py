"""Mamba-1 (S6) selective state-space block, TPU-native.

The CUDA selective-scan kernel is replaced by a *chunked* formulation
(DESIGN.md §4): an outer ``lax.scan`` over sequence chunks carries the
(B, d_inner, N) state; within a chunk a ``lax.associative_scan`` runs the
first-order recurrence in parallel.  The (B, L, d_inner, N) chunk tensor is
the only large intermediate and shards over the model axis (d_inner).

Decode keeps an O(1) recurrent state: (ssm state, conv window) — this is
what makes ``long_500k`` native for mamba-bearing archs.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig
from repro.utils import lecun_init, zeros_init


class MambaState(NamedTuple):
    h: jax.Array          # (B, d_inner, N) SSM state
    conv: jax.Array       # (B, d_conv-1, d_inner) trailing conv window


def _dims(cfg: ModelConfig):
    mc = cfg.mamba or MambaConfig()
    d_inner = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return mc, d_inner, dt_rank


def init_mamba(key, cfg: ModelConfig):
    mc, din, dtr = _dims(cfg)
    d, N = cfg.d_model, mc.d_state
    ks = jax.random.split(key, 8)
    # dt bias: inverse-softplus of dt ~ LogUniform(1e-3, 1e-1) (mamba init)
    dt = jnp.exp(jax.random.uniform(ks[0], (din,)) *
                 (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log1p(-jnp.exp(-dt))  # softplus^-1
    A_log = jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (din, N)))
    return {
        "in_proj": {"w": lecun_init(ks[1], (d, 2 * din))},
        "conv_w": lecun_init(ks[2], (mc.d_conv, din)),
        "conv_b": zeros_init(ks[3], (din,)),
        "x_proj": {"w": lecun_init(ks[4], (din, dtr + 2 * N))},
        "dt_proj": {"w": lecun_init(ks[5], (dtr, din)), "b": dt_bias},
        "A_log": A_log,
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": {"w": lecun_init(ks[6], (din, d), fan_in_axes=(0,))},
    }


def _conv1d_causal(x, w, b):
    """Depthwise causal conv.  x: (B,S,din); w: (K,din)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(K))
    return out + b.astype(x.dtype)


def _ssm_inputs(params, cfg: ModelConfig, xc, dt_rank, N):
    """xc: (B,S,din) post-conv activations -> (a, b, C) scan inputs."""
    dbc = xc @ params["x_proj"]["w"].astype(xc.dtype)
    dt, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = dt @ params["dt_proj"]["w"].astype(xc.dtype) + params["dt_proj"]["b"].astype(xc.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32))                    # (B,S,din)
    A = -jnp.exp(params["A_log"])                                   # (din,N)
    a = jnp.exp(dt[..., None] * A)                                  # (B,S,din,N)
    b = (dt * xc.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, :, None, :]
    return a, b, Cm


def mamba_forward(params, cfg: ModelConfig, x, *, chunk: int = 128,
                  return_state: bool = False):
    """Full-sequence forward.  x: (B,S,d) -> (B,S,d) [, final MambaState]."""
    mc, din, dtr = _dims(cfg)
    N = mc.d_state
    B, S, d = x.shape
    xz = x @ params["in_proj"]["w"].astype(x.dtype)
    xr, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_conv1d_causal(xr, params["conv_w"], params["conv_b"]))
    a, b, Cm = _ssm_inputs(params, cfg, xc, dtr, N)

    L = min(chunk, S)
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    nc = S // L
    a_c = a.reshape(B, nc, L, din, N).swapaxes(0, 1)   # (nc,B,L,din,N)
    b_c = b.reshape(B, nc, L, din, N).swapaxes(0, 1)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    def chunk_step(h, ab):
        ac, bc = ab                                    # (B,L,din,N)
        A_cum, B_cum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = A_cum * h[:, None] + B_cum             # (B,L,din,N)
        return h_all[:, -1], h_all

    h0 = jnp.zeros((B, din, N), jnp.float32)
    h_last, h_chunks = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    h_seq = h_chunks.swapaxes(0, 1).reshape(B, S, din, N)
    y = jnp.einsum("bsdn,bsn->bsd", h_seq, Cm.astype(jnp.float32))
    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]["w"].astype(x.dtype)
    if return_state:
        conv_tail = _conv_tail(xr, mc.d_conv)
        return out, MambaState(h_last, conv_tail)
    return out


def _conv_tail(xr, d_conv):
    """Last d_conv-1 pre-conv inputs, for decode continuation."""
    return xr[:, -(d_conv - 1):, :]


def mamba_decode(params, cfg: ModelConfig, x, state: MambaState):
    """Single-token step.  x: (B,1,d) -> (out (B,1,d), new state)."""
    mc, din, dtr = _dims(cfg)
    N = mc.d_state
    B = x.shape[0]
    xz = x @ params["in_proj"]["w"].astype(x.dtype)
    xr, z = jnp.split(xz, 2, axis=-1)                  # (B,1,din)
    win = jnp.concatenate([state.conv, xr], axis=1)    # (B,d_conv,din)
    w = params["conv_w"].astype(x.dtype)
    xc = jnp.einsum("bkd,kd->bd", win, w)[:, None, :] + params["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)
    a, b, Cm = _ssm_inputs(params, cfg, xc, dtr, N)    # (B,1,din,N)
    h = a[:, 0] * state.h + b[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))
    y = y + params["D"] * xc[:, 0].astype(jnp.float32)
    y = y.astype(x.dtype)[:, None, :] * jax.nn.silu(z)
    out = y @ params["out_proj"]["w"].astype(x.dtype)
    return out, MambaState(h, win[:, 1:])


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    mc, din, _ = _dims(cfg)
    return MambaState(jnp.zeros((batch, din, mc.d_state), jnp.float32),
                      jnp.zeros((batch, mc.d_conv - 1, din), dtype))
