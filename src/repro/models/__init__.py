"""Model substrate: unified transformer zoo + classifier zoo."""
