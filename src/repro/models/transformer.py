"""Unified sequence model covering the whole assigned zoo.

One parameterised stack supports: dense decoders (llama/qwen/granite/gemma2
flavours), encoder-only (hubert), MoE FFNs (phi3.5/olmoe/jamba), Mamba and
xLSTM mixer blocks, and VLM/audio frontends (stub embeddings per the task
carve-out).

The stack is grouped by the repeating ``layer_pattern`` period and scanned
with ``lax.scan`` over groups (keeps HLO size O(period), not O(layers) —
essential for 52–72-layer dry-run compiles).  Parameters of each group are
stacked along a leading ``num_groups`` axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, MAMBA, MLSTM, SLSTM, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import KVCache
from repro.models.layers import (dense, embed, init_dense, init_embedding,
                                 init_mlp, init_rmsnorm, mlp, rmsnorm, unembed)
from repro.models.moe import Parallel
from repro.utils import softcap as _softcap

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, p: int):
    """One layer at period position p (absolute layer ≡ p mod period)."""
    kind = cfg.layer_kind(p)
    ks = jax.random.split(key, 6)
    layer: dict[str, Any] = {"norm1": init_rmsnorm(ks[0], cfg.d_model)}
    if kind in (ATTN, ATTN_LOCAL):
        layer["mixer"] = attn_mod.init_attention(ks[1], cfg)
    elif kind == MAMBA:
        layer["mixer"] = ssm_mod.init_mamba(ks[1], cfg)
    elif kind == MLSTM:
        layer["mixer"] = xlstm_mod.init_mlstm(ks[1], cfg)
    elif kind == SLSTM:
        layer["mixer"] = xlstm_mod.init_slstm(ks[1], cfg)
    else:
        raise ValueError(kind)
    has_ffn = cfg.uses_moe(p) or (cfg.d_ff > 0 and kind not in (MLSTM, SLSTM))
    if has_ffn:
        layer["norm2"] = init_rmsnorm(ks[2], cfg.d_model)
        if cfg.uses_moe(p):
            layer["moe"] = moe_mod.init_moe(ks[3], cfg)
        else:
            layer["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.gated_mlp)
    if cfg.post_norms:
        layer["post_norm1"] = init_rmsnorm(ks[4], cfg.d_model)
        if has_ffn:
            layer["post_norm2"] = init_rmsnorm(ks[5], cfg.d_model)
    return layer


def init_lm(key, cfg: ModelConfig):
    key, gkey = jax.random.split(key)
    ks = jax.random.split(key, 5)
    params: dict[str, Any] = {}
    params["embed"] = init_embedding(ks[0], cfg.padded_vocab, cfg.d_model)
    if cfg.frontend != "token":
        params["frontend_proj"] = init_dense(ks[1], cfg.frontend_dim, cfg.d_model)
        if cfg.frontend == "audio_frames":
            params["mask_embed"] = jax.random.normal(ks[2], (cfg.d_model,)) * 0.02
    params["final_norm"] = init_rmsnorm(ks[3], cfg.d_model)
    if not cfg.tie_embeddings and not cfg.is_encoder:
        params["lm_head"] = init_dense(ks[4], cfg.d_model, cfg.padded_vocab)
    if cfg.is_encoder:
        params["enc_head"] = init_dense(ks[4], cfg.d_model, cfg.padded_vocab)

    def init_group(gkey):
        lkeys = jax.random.split(gkey, cfg.period)
        return {f"p{p}": _init_layer(lkeys[p], cfg, p) for p in range(cfg.period)}

    params["groups"] = jax.vmap(init_group)(jax.random.split(gkey, cfg.num_groups))
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, batch):
    """Returns (x (B,S,d), positions (B,S), loss_mask (B,S) or None)."""
    dt = cfg.act_dtype
    if cfg.frontend == "token":
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens, dt)
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        mask = None
    elif cfg.frontend == "vision_patches":
        tokens = batch["tokens"]
        patches = batch["patches"].astype(dt)
        xt = embed(params["embed"], tokens, dt)
        xp = dense(params["frontend_proj"], patches)
        x = jnp.concatenate([xp, xt], axis=1)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        mask = jnp.concatenate(
            [jnp.zeros(xp.shape[:2], bool), jnp.ones(xt.shape[:2], bool)], axis=1)
    elif cfg.frontend == "audio_frames":
        frames = batch["frames"].astype(dt)
        x = dense(params["frontend_proj"], frames)
        m = batch["mask"]                                    # True = masked out
        x = jnp.where(m[..., None], params["mask_embed"].astype(dt), x)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        mask = m
    else:
        raise ValueError(cfg.frontend)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    return x, pos, mask


def _apply_layer(layer, cfg: ModelConfig, p: int, x, pos, par: Parallel,
                 mode: str, cache=None, decode_pos=None):
    """mode: train | prefill | decode.  Returns (x, aux, new_cache)."""
    kind = cfg.layer_kind(p)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(layer["norm1"], x, cfg.norm_eps)
    new_cache = None
    if kind in (ATTN, ATTN_LOCAL):
        if mode == "decode":
            h, new_cache = attn_mod.attention_decode(layer["mixer"], cfg, h,
                                                     cache, decode_pos, kind=kind)
        else:
            h, kv = attn_mod.attention(layer["mixer"], cfg, h, pos, kind=kind,
                                       use_pallas=par.use_pallas,
                                       impl=par.attn_impl, par=par)
            if mode == "prefill":
                new_cache = KVCache(*kv)
    elif kind == MAMBA:
        if mode == "decode":
            h, new_cache = ssm_mod.mamba_decode(layer["mixer"], cfg, h, cache)
        elif mode == "prefill":
            h, new_cache = ssm_mod.mamba_forward(layer["mixer"], cfg, h,
                                                 return_state=True)
        else:
            h = ssm_mod.mamba_forward(layer["mixer"], cfg, h)
    elif kind == MLSTM:
        if mode == "decode":
            h, new_cache = xlstm_mod.mlstm_decode(layer["mixer"], cfg, h, cache)
        elif mode == "prefill":
            h, new_cache = xlstm_mod.mlstm_forward(layer["mixer"], cfg, h,
                                                   return_state=True)
        else:
            h = xlstm_mod.mlstm_forward(layer["mixer"], cfg, h)
    elif kind == SLSTM:
        if mode == "decode":
            h, new_cache = xlstm_mod.slstm_decode(layer["mixer"], cfg, h, cache)
        elif mode == "prefill":
            h, new_cache = xlstm_mod.slstm_forward(layer["mixer"], cfg, h,
                                                   return_state=True)
        else:
            h = xlstm_mod.slstm_forward(layer["mixer"], cfg, h)
    if cfg.post_norms:
        h = rmsnorm(layer["post_norm1"], h, cfg.norm_eps)
    x = x + h
    if "moe" in layer or "mlp" in layer:
        h = rmsnorm(layer["norm2"], x, cfg.norm_eps)
        if "moe" in layer:
            h, aux = moe_mod.moe_apply(layer["moe"], cfg, h, par)
        else:
            h = mlp(layer["mlp"], h, cfg.mlp_act)
        if cfg.post_norms:
            h = rmsnorm(layer["post_norm2"], h, cfg.norm_eps)
        x = x + h
    return x, aux, new_cache


def _readout(params, cfg: ModelConfig, x, par: Parallel = Parallel()):
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.is_encoder:
        logits = dense(params["enc_head"], x)
    elif cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["lm_head"], x)
    if cfg.final_softcap:
        logits = _softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if par.logits_spec is not None:
        logits = jax.lax.with_sharding_constraint(logits, par.logits_spec)
    return logits


def forward(params, cfg: ModelConfig, batch, par: Parallel = Parallel(),
            *, mode: str = "train"):
    """Full-sequence pass.

    Returns (logits, aux_loss) for mode="train";
    (logits, aux_loss, caches) for mode="prefill" (caches stacked per group).
    """
    x, pos, _ = _embed_inputs(params, cfg, batch)

    def group_fn(carry, gparams):
        x, aux = carry
        new_caches = {}
        for p in range(cfg.period):
            x, aux_p, c = _apply_layer(gparams[f"p{p}"], cfg, p, x, pos, par, mode)
            aux = aux + aux_p
            if mode == "prefill":
                new_caches[f"p{p}"] = c
        if par.resid_spec is not None:
            x = jax.lax.with_sharding_constraint(x, par.resid_spec)
        return (x, aux), (new_caches if mode == "prefill" else None)

    if cfg.remat == "full":
        group_fn = jax.checkpoint(group_fn, prevent_cse=False)
    elif cfg.remat == "dots":
        group_fn = jax.checkpoint(
            group_fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    (x, aux), caches = jax.lax.scan(group_fn, (x, jnp.zeros((), jnp.float32)),
                                    params["groups"])
    if mode == "prefill" and par.prefill_last_only:
        # serving: only the last position's logits are needed to start
        # decode — skips a (B,S,V) readout (+ its vocab-parallel collective)
        logits = _readout(params, cfg, x[:, -1:, :], par)
        return logits, aux, caches
    logits = _readout(params, cfg, x, par)
    if mode == "prefill":
        return logits, aux, caches
    return logits, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(params, cfg: ModelConfig, batch, par: Parallel = Parallel()):
    """Causal-LM / masked-prediction loss.  Returns (loss, metrics)."""
    logits, aux = forward(params, cfg, batch, par, mode="train")
    logits = logits.astype(jnp.float32)
    if cfg.is_encoder:
        labels = batch["labels"]
        m = batch["mask"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(m), 1)
        ce = jnp.sum(nll * m) / denom
    elif cfg.frontend == "vision_patches":
        tokens = batch["tokens"]
        P = batch["patches"].shape[1]
        text_logits = logits[:, P:, :]
        logp = jax.nn.log_softmax(text_logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]
        ce = jnp.mean(nll)
    else:
        tokens = batch["tokens"]
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]
        ce = jnp.mean(nll)
    aux_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
    loss = ce + aux_w * aux / max(cfg.num_layers, 1)
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Stacked (num_groups-leading) cache pytree for all layers."""
    dtype = dtype or cfg.act_dtype

    def one(p):
        kind = cfg.layer_kind(p)
        if kind in (ATTN, ATTN_LOCAL):
            return attn_mod.init_kv_cache(cfg, batch, max_len, dtype)
        if kind == MAMBA:
            return ssm_mod.init_mamba_state(cfg, batch, dtype)
        if kind == MLSTM:
            return xlstm_mod.init_mlstm_state(cfg, batch, dtype)
        if kind == SLSTM:
            return xlstm_mod.init_slstm_state(cfg, batch, dtype)
        raise ValueError(kind)

    single = {f"p{p}": one(p) for p in range(cfg.period)}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_groups,) + a.shape).copy(), single)


def decode_step(params, cfg: ModelConfig, tokens, caches, pos,
                par: Parallel = Parallel()):
    """One decode step.  tokens: (B,1) int32; pos: scalar int32 (current
    write position).  Returns (logits (B,1,V), new caches).

    The stacked caches ride the scan CARRY and are updated in place:
    attention layers DUS one token at [g, :, pos]; recurrent layers
    (mamba/xlstm) update their (small) per-group state slot.  This keeps
    per-step HBM cache traffic at O(read) + O(token), not O(cache) —
    see EXPERIMENTS.md §Perf (qwen3-32b × decode_32k iteration)."""
    dt = cfg.act_dtype
    x = embed(params["embed"], tokens, dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)

    if par.decode_cache != "carry":
        def group_fn_ys(x, scanned):
            gparams, gcache = scanned
            new_caches = {}
            for p in range(cfg.period):
                x, _, c = _apply_layer(gparams[f"p{p}"], cfg, p, x, None, par,
                                       "decode", cache=gcache[f"p{p}"],
                                       decode_pos=pos)
                new_caches[f"p{p}"] = c
            return x, new_caches

        x, new_caches = jax.lax.scan(group_fn_ys, x, (params["groups"], caches))
        logits = _readout(params, cfg, x, par)
        return logits, new_caches

    def group_fn(carry, scanned):
        x, caches = carry
        gparams, g = scanned
        for p in range(cfg.period):
            kind = cfg.layer_kind(p)
            layer = gparams[f"p{p}"]
            if kind in (ATTN, ATTN_LOCAL):
                h = rmsnorm(layer["norm1"], x, cfg.norm_eps)
                h, new_kv = attn_mod.attention_decode_stacked(
                    layer["mixer"], cfg, h, caches[f"p{p}"], g, pos, kind=kind)
                if cfg.post_norms:
                    h = rmsnorm(layer["post_norm1"], h, cfg.norm_eps)
                x = x + h
                caches = dict(caches, **{f"p{p}": new_kv})
                if "moe" in layer or "mlp" in layer:
                    h = rmsnorm(layer["norm2"], x, cfg.norm_eps)
                    if "moe" in layer:
                        h, _ = moe_mod.moe_apply(layer["moe"], cfg, h, par)
                    else:
                        h = mlp(layer["mlp"], h, cfg.mlp_act)
                    if cfg.post_norms:
                        h = rmsnorm(layer["post_norm2"], h, cfg.norm_eps)
                    x = x + h
            else:
                gcache = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, g, 0,
                                                           keepdims=False),
                    caches[f"p{p}"])
                x, _, new_c = _apply_layer(layer, cfg, p, x, None, par,
                                           "decode", cache=gcache,
                                           decode_pos=pos)
                stacked = jax.tree.map(
                    lambda allc, n: jax.lax.dynamic_update_index_in_dim(
                        allc, n.astype(allc.dtype), g, 0),
                    caches[f"p{p}"], new_c)
                caches = dict(caches, **{f"p{p}": stacked})
        return (x, caches), None

    G = cfg.num_groups
    (x, new_caches), _ = jax.lax.scan(
        group_fn, (x, caches), (params["groups"], jnp.arange(G)))
    logits = _readout(params, cfg, x, par)
    return logits, new_caches
