"""Basic neural-net layers (functional: init_* returns a params dict,
*_apply consumes it).  Parameter key names are load-bearing: the sharding
rules in ``repro.sharding.rules`` match on them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import lecun_init, normal_init, ones_init, zeros_init


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(key, dim: int):
    return {"scale": jnp.zeros((dim,), jnp.float32)}  # (1 + scale) convention


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(key, dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, bias: bool = False, stddev: float | None = None):
    kw, kb = jax.random.split(key)
    if stddev is None:
        w = lecun_init(kw, (d_in, d_out))
    else:
        w = normal_init(kw, (d_in, d_out), stddev=stddev)
    p = {"w": w}
    if bias:
        p["b"] = zeros_init(kb, (d_out,))
    return p


def dense(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, dim: int):
    return {"embedding": normal_init(key, (vocab, dim), stddev=0.02)}


def embed(params, ids, dtype):
    return params["embedding"].astype(dtype)[ids]


def unembed(params, x):
    """Tied read-out: x @ E^T."""
    return x @ params["embedding"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                         # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated or plain)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool):
    ks = jax.random.split(key, 3)
    p = {"w_up": lecun_init(ks[0], (d_model, d_ff)),
         "w_down": lecun_init(ks[1], (d_ff, d_model), fan_in_axes=(0,))}
    if gated:
        p["w_gate"] = lecun_init(ks[2], (d_model, d_ff))
    return p


def mlp(params, x, act: str = "silu"):
    actfn = jax.nn.silu if act == "silu" else (lambda v: jax.nn.gelu(v, approximate=True))
    up = x @ params["w_up"].astype(x.dtype)
    if "w_gate" in params:
        up = actfn(x @ params["w_gate"].astype(x.dtype)) * up
    else:
        up = actfn(up)
    return up @ params["w_down"].astype(x.dtype)
