"""Classifier zoo for the OSCAR global model (paper Tables I & II).

Scaled-to-16×16 analogues of the paper's backbones: ResNet-18/50/101
(basic/bottleneck residual stacks), VGG-16 (plain conv stacks),
DenseNet-121 (dense connectivity), ViT-B/16 (patch transformer).  Width
and depth are reduced for the CPU budget but the family ordering of
capacity (and the paper's Table II trend) is preserved.

BatchNorm → GroupNorm substitution (noted in DESIGN.md §8): avoids
cross-client running-statistics leakage and state plumbing; standard in
FL implementations for exactly this reason.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.utils import lecun_init, zeros_init


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def _init_conv(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout)) / math.sqrt(fan_in)
    return {"w": w.astype(jnp.float32)}


def _conv(p, x, stride=1):
    return jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _init_gn(key, ch):
    return {"scale": jnp.ones((ch,)), "bias": jnp.zeros((ch,))}


def _gn(p, x, groups=4):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(B, H, W, C) * p["scale"] + p["bias"]


def _init_fc(key, din, dout):
    return {"w": lecun_init(key, (din, dout)), "b": zeros_init(key, (dout,))}


def _fc(p, x):
    return x @ p["w"] + p["b"]


# ---------------------------------------------------------------------------
# ResNet family
# ---------------------------------------------------------------------------

def _init_basic_block(key, cin, cout, stride):
    ks = jax.random.split(key, 5)
    p = {"c1": _init_conv(ks[0], 3, 3, cin, cout), "n1": _init_gn(ks[1], cout),
         "c2": _init_conv(ks[2], 3, 3, cout, cout), "n2": _init_gn(ks[3], cout)}
    if stride != 1 or cin != cout:
        p["proj"] = _init_conv(ks[4], 1, 1, cin, cout)
    return p


def _basic_block(p, x, stride):
    h = jax.nn.relu(_gn(p["n1"], _conv(p["c1"], x, stride)))
    h = _gn(p["n2"], _conv(p["c2"], h))
    sc = _conv(p["proj"], x, stride) if "proj" in p else x
    return jax.nn.relu(h + sc)


def _init_bottleneck(key, cin, cout, stride):
    mid = cout // 4
    ks = jax.random.split(key, 7)
    p = {"c1": _init_conv(ks[0], 1, 1, cin, mid), "n1": _init_gn(ks[1], mid),
         "c2": _init_conv(ks[2], 3, 3, mid, mid), "n2": _init_gn(ks[3], mid),
         "c3": _init_conv(ks[4], 1, 1, mid, cout), "n3": _init_gn(ks[5], cout)}
    if stride != 1 or cin != cout:
        p["proj"] = _init_conv(ks[6], 1, 1, cin, cout)
    return p


def _bottleneck(p, x, stride):
    h = jax.nn.relu(_gn(p["n1"], _conv(p["c1"], x)))
    h = jax.nn.relu(_gn(p["n2"], _conv(p["c2"], h, stride)))
    h = _gn(p["n3"], _conv(p["c3"], h))
    sc = _conv(p["proj"], x, stride) if "proj" in p else x
    return jax.nn.relu(h + sc)


_RESNETS = {
    # name: (block kind, blocks per stage, widths)
    "resnet18": ("basic", (2, 2, 2), (16, 32, 64)),
    "resnet50": ("bottleneck", (2, 3, 4), (32, 64, 128)),
    "resnet101": ("bottleneck", (3, 4, 10), (32, 64, 128)),
}


def _resnet_layout(name):
    kind, reps, widths = _RESNETS[name]
    layout = []
    cin = widths[0]
    for s, (rep, w) in enumerate(zip(reps, widths)):
        for b in range(rep):
            layout.append((cin, w, 2 if (b == 0 and s > 0) else 1))
            cin = w
    return kind, layout, widths[0], cin


def _init_resnet(key, name, num_classes, in_ch):
    kind, layout, w0, cout = _resnet_layout(name)
    ks = jax.random.split(key, 3)
    params = {"stem": _init_conv(ks[0], 3, 3, in_ch, w0),
              "stem_n": _init_gn(ks[1], w0), "blocks": []}
    bk = jax.random.split(ks[2], len(layout))
    init = _init_basic_block if kind == "basic" else _init_bottleneck
    for i, (cin, w, stride) in enumerate(layout):
        params["blocks"].append(init(bk[i], cin, w, stride))
    params["fc"] = _init_fc(jax.random.fold_in(key, 7), cout, num_classes)
    return params


def _resnet_apply(params, name, x):
    kind, layout, _, _ = _resnet_layout(name)
    h = jax.nn.relu(_gn(params["stem_n"], _conv(params["stem"], x)))
    fn = _basic_block if kind == "basic" else _bottleneck
    for blk, (_, _, stride) in zip(params["blocks"], layout):
        h = fn(blk, h, stride)
    h = jnp.mean(h, axis=(1, 2))
    return _fc(params["fc"], h)


# ---------------------------------------------------------------------------
# VGG
# ---------------------------------------------------------------------------

def _init_vgg(key, num_classes, in_ch):
    cfg = [(16, 2), (32, 2), (64, 3)]  # == _VGG_CFG
    layers = []
    k = key
    cin = in_ch
    for w, rep in cfg:
        for _ in range(rep):
            k, k1, k2 = jax.random.split(k, 3)
            layers.append({"c": _init_conv(k1, 3, 3, cin, w), "n": _init_gn(k2, w)})
            cin = w
    k, k1, k2 = jax.random.split(k, 3)
    return {"layers": layers,
            "fc1": _init_fc(k1, cin * 2 * 2, 128),
            "fc2": _init_fc(k2, 128, num_classes)}


_VGG_CFG = [(16, 2), (32, 2), (64, 3)]


def _vgg_apply(params, x):
    h = x
    i = 0
    for w, rep in _VGG_CFG:
        for _ in range(rep):
            l = params["layers"][i]
            h = jax.nn.relu(_gn(l["n"], _conv(l["c"], h)))
            i += 1
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(_fc(params["fc1"], h))
    return _fc(params["fc2"], h)


# ---------------------------------------------------------------------------
# DenseNet
# ---------------------------------------------------------------------------

def _init_densenet(key, num_classes, in_ch, growth=8, blocks=(4, 4, 4)):
    k = key
    k, k1 = jax.random.split(k)
    params = {"stem": _init_conv(k1, 3, 3, in_ch, 2 * growth), "dense": [],
              "trans": []}
    ch = 2 * growth
    for bi, nl in enumerate(blocks):
        layers = []
        for _ in range(nl):
            k, k1, k2 = jax.random.split(k, 3)
            layers.append({"n": _init_gn(k1, ch), "c": _init_conv(k2, 3, 3, ch, growth)})
            ch += growth
        params["dense"].append(layers)
        if bi < len(blocks) - 1:
            k, k1, k2 = jax.random.split(k, 3)
            out = ch // 2
            params["trans"].append({"n": _init_gn(k1, ch), "c": _init_conv(k2, 1, 1, ch, out)})
            ch = out
    k, k1, k2 = jax.random.split(k, 3)
    params["final_n"] = _init_gn(k1, ch)
    params["fc"] = _init_fc(k2, ch, num_classes)
    return params


def _densenet_apply(params, x):
    h = _conv(params["stem"], x)
    for bi, layers in enumerate(params["dense"]):
        for l in layers:
            out = _conv(l["c"], jax.nn.relu(_gn(l["n"], h)))
            h = jnp.concatenate([h, out], axis=-1)
        if bi < len(params["trans"]):
            t = params["trans"][bi]
            h = _conv(t["c"], jax.nn.relu(_gn(t["n"], h)))
            h = jax.lax.reduce_window(h, 0.0, jax.lax.add,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    h = jax.nn.relu(_gn(params["final_n"], h))
    h = jnp.mean(h, axis=(1, 2))
    return _fc(params["fc"], h)


# ---------------------------------------------------------------------------
# ViT
# ---------------------------------------------------------------------------

def _init_vit(key, num_classes, in_ch, d=96, layers=4, heads=4, patch=4):
    k = key
    k, k1, k2, k3 = jax.random.split(k, 4)
    params = {"patch": _init_fc(k1, patch * patch * in_ch, d),
              "pos": jax.random.normal(k2, (1 + (16 // patch) ** 2, d)) * 0.02,
              "cls": jax.random.normal(k3, (d,)) * 0.02,
              "blocks": []}
    for _ in range(layers):
        k, k1, k2, k3, k4 = jax.random.split(k, 5)
        params["blocks"].append({
            "qkv": _init_fc(k1, d, 3 * d), "proj": _init_fc(k2, d, d),
            "up": _init_fc(k3, d, 4 * d), "down": _init_fc(k4, 4 * d, d),
            "n1": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "n2": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}})
    k, k1 = jax.random.split(k)
    params["fc"] = _init_fc(k1, d, num_classes)
    return params


def _ln_p(p, x):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]


_VIT_META = (96, 4, 4)  # (d, heads, patch)


def _vit_apply(params, x):
    d, heads, patch = _VIT_META
    B, H, W, C = x.shape
    t = x.reshape(B, H // patch, patch, W // patch, patch, C)
    t = t.transpose(0, 1, 3, 2, 4, 5).reshape(B, -1, patch * patch * C)
    t = _fc(params["patch"], t)
    cls = jnp.broadcast_to(params["cls"], (B, 1, d))
    t = jnp.concatenate([cls, t], axis=1) + params["pos"]
    hd = d // heads
    for blk in params["blocks"]:
        h = _ln_p(blk["n1"], t)
        qkv = _fc(blk["qkv"], h).reshape(B, -1, 3, heads, hd)
        q, k_, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        a = jax.nn.softmax(jnp.einsum("bqhd,bkhd->bhqk", q, k_) * hd ** -0.5, -1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, -1, d)
        t = t + _fc(blk["proj"], o)
        h = _ln_p(blk["n2"], t)
        t = t + _fc(blk["down"], jax.nn.gelu(_fc(blk["up"], h)))
    return _fc(params["fc"], t[:, 0])


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

CLASSIFIERS = ["resnet18", "vgg16", "resnet50", "resnet101", "densenet121",
               "vit_b16"]


def init_classifier(key, name: str, num_classes: int, in_ch: int = 3):
    if name in _RESNETS:
        return _init_resnet(key, name, num_classes, in_ch)
    if name == "vgg16":
        return _init_vgg(key, num_classes, in_ch)
    if name == "densenet121":
        return _init_densenet(key, num_classes, in_ch)
    if name == "vit_b16":
        return _init_vit(key, num_classes, in_ch)
    raise ValueError(name)


def classifier_apply(params, name: str, x):
    if name in _RESNETS:
        return _resnet_apply(params, name, x)
    if name == "vgg16":
        return _vgg_apply(params, x)
    if name == "densenet121":
        return _densenet_apply(params, x)
    if name == "vit_b16":
        return _vit_apply(params, x)
    raise ValueError(name)


def classifier_param_count(params) -> int:
    import numpy as np
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params)
               if hasattr(l, "shape"))
