"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM
(scalar memory), both with exponential gating and max-stabilisers.

mLSTM is computed *chunkwise-parallel* (linear-attention style): intra-chunk
quadratic matmuls feed the MXU, inter-chunk state is carried by an outer
``lax.scan``.  The chunkwise form is algebraically identical to the paper's
recurrence (the running stabiliser ``m_t = max_s (lf_t - lf_s + i_s, lf_t +
m_0)`` telescopes), verified against the step-by-step recurrence in tests.

sLSTM has a true hidden-state recurrence (gates see h_{t-1}), so it runs as
a sequential ``lax.scan`` — O(1) state makes 500k-context decode native.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, XLSTMConfig
from repro.models.layers import init_rmsnorm, rmsnorm
from repro.utils import lecun_init, zeros_init

NEG = -1e30


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, hd, hd) matrix memory
    n: jax.Array   # (B, H, hd) normaliser
    m: jax.Array   # (B, H) stabiliser
    conv: jax.Array  # (B, K-1, din) conv window


class SLSTMState(NamedTuple):
    h: jax.Array   # (B, d)
    c: jax.Array   # (B, d)
    n: jax.Array   # (B, d)
    m: jax.Array   # (B, d)


def _xc(cfg: ModelConfig) -> XLSTMConfig:
    return cfg.xlstm or XLSTMConfig()


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    xc = _xc(cfg)
    d, H = cfg.d_model, cfg.num_heads
    din = int(xc.proj_factor * d)
    ks = jax.random.split(key, 10)
    return {
        "in_proj": {"w": lecun_init(ks[0], (d, 2 * din))},
        "conv_w": lecun_init(ks[1], (xc.conv_kernel, din)),
        "conv_b": zeros_init(ks[2], (din,)),
        "wq": {"w": lecun_init(ks[3], (din, din))},
        "wk": {"w": lecun_init(ks[4], (din, din))},
        "wv": {"w": lecun_init(ks[5], (din, din))},
        "w_igate": {"w": lecun_init(ks[6], (din, H)), "b": zeros_init(ks[6], (H,))},
        "w_fgate": {"w": lecun_init(ks[7], (din, H)),
                    "b": jnp.full((H,), 3.0, jnp.float32)},  # open forget gates
        "head_norm": init_rmsnorm(ks[8], din),
        "out_proj": {"w": lecun_init(ks[9], (din, d), fan_in_axes=(0,))},
    }


def _conv_silu(x, w, b):
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(K))
    return jax.nn.silu(out + b.astype(x.dtype))


def _mlstm_chunk(q, k, v, ig, lf, state):
    """One chunk of the stabilised chunkwise mLSTM.

    q,k,v: (B,H,L,hd) (k pre-scaled by hd^-0.5); ig/lf: (B,H,L) input-gate
    logits and log-sigmoid forget logits; state: (C0 (B,H,hd,hd), n0, m0).
    Returns (h (B,H,L,hd), new state tuple).
    """
    C0, n0, m0 = state
    B, H, L, hd = q.shape
    lfc = jnp.cumsum(lf, axis=-1)                                # (B,H,L)
    # intra-chunk log weights a[t,s] = lfc_t - lfc_s + ig_s, s <= t
    A = lfc[..., :, None] - lfc[..., None, :] + ig[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    A = jnp.where(tri, A, NEG)
    b = lfc + m0[..., None]                                      # inter log weight
    m_t = jnp.maximum(jnp.max(A, axis=-1), b)                    # (B,H,L)
    D = jnp.exp(A - m_t[..., None])                              # (B,H,L,L)
    ib = jnp.exp(b - m_t)                                        # (B,H,L)
    S_qk = jnp.einsum("bhtd,bhsd->bhts", q, k)
    num = jnp.einsum("bhts,bhsd->bhtd", S_qk * D, v)
    num = num + ib[..., None] * jnp.einsum("bhtd,bhdv->bhtv", q, C0)
    n_t = jnp.einsum("bhts,bhsd->bhtd", D, k) + ib[..., None] * n0[..., None, :]
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", n_t, q)),
                        jnp.exp(-m_t))
    h = num / denom[..., None]
    # ---- chunk-end state ----
    lf_end = lfc[..., -1]
    w_log = lf_end[..., None] - lfc + ig                         # (B,H,L)
    m_new = jnp.maximum(lf_end + m0, jnp.max(w_log, axis=-1))
    w = jnp.exp(w_log - m_new[..., None])
    carry_scale = jnp.exp(lf_end + m0 - m_new)
    C_new = carry_scale[..., None, None] * C0 + jnp.einsum("bhs,bhsd,bhsv->bhdv", w, k, v)
    n_new = carry_scale[..., None] * n0 + jnp.einsum("bhs,bhsd->bhd", w, k)
    return h, (C_new, n_new, m_new)


def mlstm_forward(params, cfg: ModelConfig, x, *, chunk: int = 256,
                  return_state: bool = False):
    xc = _xc(cfg)
    H = cfg.num_heads
    B, S, d = x.shape
    din = int(xc.proj_factor * d)
    hd = din // H
    xm, z = jnp.split(x @ params["in_proj"]["w"].astype(x.dtype), 2, axis=-1)
    xconv = _conv_silu(xm, params["conv_w"], params["conv_b"])

    def heads(t):  # (B,S,din) -> (B,H,S,hd) float32
        return t.reshape(B, S, H, hd).transpose(0, 2, 1, 3).astype(jnp.float32)

    q = heads(xconv @ params["wq"]["w"].astype(x.dtype))
    k = heads(xconv @ params["wk"]["w"].astype(x.dtype)) * (hd ** -0.5)
    v = heads(xm @ params["wv"]["w"].astype(x.dtype))
    ig = (xm @ params["w_igate"]["w"].astype(x.dtype) + params["w_igate"]["b"].astype(x.dtype))
    fg = (xm @ params["w_fgate"]["w"].astype(x.dtype) + params["w_fgate"]["b"].astype(x.dtype))
    ig = ig.transpose(0, 2, 1).astype(jnp.float32)               # (B,H,S)
    lf = jax.nn.log_sigmoid(fg.transpose(0, 2, 1).astype(jnp.float32))

    L = min(chunk, S)
    assert S % L == 0
    nc = S // L

    def to_chunks(t, trailing):
        return t.reshape(B, H, nc, L, *trailing).transpose(2, 0, 1, 3, *range(4, 4 + len(trailing)))

    qc, kc, vc = (to_chunks(t, (hd,)) for t in (q, k, v))
    igc, lfc = (to_chunks(t, ()) for t in (ig, lf))

    def step(state, inp):
        qi, ki, vi, igi, lfi = inp
        h, new_state = _mlstm_chunk(qi, ki, vi, igi, lfi, state)
        return new_state, h

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), NEG, jnp.float32)
    state, h_chunks = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, igc, lfc))
    h = h_chunks.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, din).astype(x.dtype)
    h = rmsnorm(params["head_norm"], h, cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ params["out_proj"]["w"].astype(x.dtype)
    if return_state:
        conv_tail = xm[:, -(xc.conv_kernel - 1):, :]
        return out, MLSTMState(state[0], state[1], state[2], conv_tail)
    return out


def mlstm_decode(params, cfg: ModelConfig, x, state: MLSTMState):
    """x: (B,1,d) single-token step."""
    xc = _xc(cfg)
    H = cfg.num_heads
    B, _, d = x.shape
    din = int(xc.proj_factor * d)
    hd = din // H
    xm, z = jnp.split(x @ params["in_proj"]["w"].astype(x.dtype), 2, axis=-1)
    win = jnp.concatenate([state.conv, xm], axis=1)              # (B,K,din)
    w = params["conv_w"].astype(x.dtype)
    xconv = jax.nn.silu(jnp.einsum("bkd,kd->bd", win, w) + params["conv_b"].astype(x.dtype))

    def heads(t):
        return t.reshape(B, H, hd).astype(jnp.float32)

    q = heads(xconv @ params["wq"]["w"].astype(x.dtype))
    k = heads(xconv @ params["wk"]["w"].astype(x.dtype)) * (hd ** -0.5)
    v = heads(xm[:, 0] @ params["wv"]["w"].astype(x.dtype))
    ig = (xm[:, 0] @ params["w_igate"]["w"].astype(x.dtype) + params["w_igate"]["b"].astype(x.dtype)).astype(jnp.float32)
    lf = jax.nn.log_sigmoid((xm[:, 0] @ params["w_fgate"]["w"].astype(x.dtype) + params["w_fgate"]["b"].astype(x.dtype)).astype(jnp.float32))
    m_new = jnp.maximum(lf + state.m, ig)
    fs = jnp.exp(lf + state.m - m_new)
    is_ = jnp.exp(ig - m_new)
    C = fs[..., None, None] * state.C + is_[..., None, None] * jnp.einsum("bhd,bhv->bhdv", k, v)
    n = fs[..., None] * state.n + is_[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), jnp.exp(-m_new))
    h = (num / denom[..., None]).reshape(B, din).astype(x.dtype)
    h = rmsnorm(params["head_norm"], h, cfg.norm_eps)
    out = (h[:, None, :] * jax.nn.silu(z)) @ params["out_proj"]["w"].astype(x.dtype)
    return out, MLSTMState(C, n, m_new, win[:, 1:])


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype) -> MLSTMState:
    xc = _xc(cfg)
    H = cfg.num_heads
    din = int(xc.proj_factor * cfg.d_model)
    hd = din // H
    return MLSTMState(
        jnp.zeros((batch, H, hd, hd), jnp.float32),
        jnp.zeros((batch, H, hd), jnp.float32),
        jnp.full((batch, H), NEG, jnp.float32),
        jnp.zeros((batch, xc.conv_kernel - 1, din), dtype))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    xc = _xc(cfg)
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    dff = int(xc.slstm_proj_factor * d)
    ks = jax.random.split(key, 7)
    return {
        # input weights for gates z,i,f,o stacked: (d, 4d)
        "w_x": {"w": lecun_init(ks[0], (d, 4 * d))},
        # block-diagonal recurrent weights per head: (H, hd, 4*hd)
        "w_r": lecun_init(ks[1], (H, hd, 4 * hd)),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), jnp.full((d,), 3.0),
                              jnp.zeros((d,))]).astype(jnp.float32),
        "head_norm": init_rmsnorm(ks[2], d),
        "up_proj": {"w": lecun_init(ks[3], (d, 2 * dff))},
        "down_proj": {"w": lecun_init(ks[4], (dff, d), fan_in_axes=(0,))},
    }


def _slstm_cell(params, cfg: ModelConfig, xg, state: SLSTMState):
    """One time step.  xg: (B, 4d) pre-computed input contribution."""
    H = cfg.num_heads
    d = cfg.d_model
    hd = d // H
    B = xg.shape[0]
    h_heads = state.h.reshape(B, H, hd)
    rec = jnp.einsum("bhd,hde->bhe", h_heads, params["w_r"]).reshape(B, 4 * d)
    g = (xg + rec + params["b"]).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zt)
    o = jax.nn.sigmoid(ot)
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + state.m, it)
    fs = jnp.exp(lf + state.m - m_new)
    is_ = jnp.exp(it - m_new)
    c = fs * state.c + is_ * z
    n = fs * state.n + is_
    h = o * c / jnp.maximum(n, 1e-6)
    return SLSTMState(h, c, n, m_new)


def slstm_forward(params, cfg: ModelConfig, x, *, return_state: bool = False):
    B, S, d = x.shape
    xg = x @ params["w_x"]["w"].astype(x.dtype)                  # (B,S,4d)

    def step(state, xg_t):
        new = _slstm_cell(params, cfg, xg_t, state)
        return new, new.h

    state0 = init_slstm_state(cfg, B, x.dtype)
    state, hs = jax.lax.scan(step, state0, xg.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)                        # (B,S,d)
    h = rmsnorm(params["head_norm"], h, cfg.norm_eps)
    up, gate = jnp.split(h @ params["up_proj"]["w"].astype(x.dtype), 2, axis=-1)
    out = (up * jax.nn.gelu(gate, approximate=True)) @ params["down_proj"]["w"].astype(x.dtype)
    if return_state:
        return out, state
    return out


def slstm_decode(params, cfg: ModelConfig, x, state: SLSTMState):
    B = x.shape[0]
    xg = (x[:, 0] @ params["w_x"]["w"].astype(x.dtype))
    new = _slstm_cell(params, cfg, xg, state)
    h = new.h.astype(x.dtype)[:, None, :]
    h = rmsnorm(params["head_norm"], h, cfg.norm_eps)
    up, gate = jnp.split(h @ params["up_proj"]["w"].astype(x.dtype), 2, axis=-1)
    out = (up * jax.nn.gelu(gate, approximate=True)) @ params["down_proj"]["w"].astype(x.dtype)
    return out, new


def init_slstm_state(cfg: ModelConfig, batch: int, dtype) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((batch, d), NEG, jnp.float32))
