"""Guidance strategies + the single reverse-process core.

Every sampler in the repo (classifier-free — paper Eq. 8/9, classifier-
guided — Eq. 4 / FedCADO, and unconditional) is the SAME ancestral/DDIM
loop differing only in how the per-step score ε̂ is produced.  That
difference is factored into a ``GuidanceStrategy``; ``reverse_sample`` owns
the respacing, the scan loop, the per-step noise draw, and the fused
guidance-combine + ancestral update (Pallas ``kernels/cfg_fuse`` when
enabled).

A strategy answers two questions per step:

* ``eps(params, dc, x, t, ab_t, aux) -> (eps_c, eps_u, s)`` — the pair of
  score evaluations fed to the fused update ``(1+s)·ε_c − s·ε_u``.  A
  strategy whose guidance is already folded into a single ε̂ (classifier-
  guided, unconditional) returns ``eps_u=None`` and the core applies the
  plain ancestral step — bit-identical to the historical samplers.
* ``prepare(params, dc) -> aux`` — per-trajectory precompute hoisted out
  of the scan (e.g. the stacked cond/uncond conditioning batch).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import dit_apply
from repro.diffusion.schedule import NoiseSchedule


def _strictly_decreasing(ts, num_steps: int):
    """Enforce a strictly-decreasing integer trajectory ending at 0.

    Rounding the respaced linspace can emit repeated t values (certain
    when ``num_steps > T``; a float-precision hazard near it), and a
    repeated timestep is a wasted denoiser call: ᾱ_t == ᾱ_prev makes the
    update pure re-noising.  The fix is the tightest strictly-decreasing
    envelope under the rounded trajectory (``cummin`` of ``ts + i`` minus
    ``i``), floored so the tail still reaches 0 — the identity whenever
    the input is already strictly decreasing, which is every collision-
    free case, so historical trajectories are reproduced bit-exactly.
    """
    i = jnp.arange(num_steps)
    ts = jax.lax.cummin(ts + i) - i            # strictly decreasing
    return jnp.maximum(ts, num_steps - 1 - i)  # …and still ends at 0


def respaced_ts(T: int, num_steps: int):
    if num_steps > T:
        raise ValueError(
            f"num_steps={num_steps} > T={T}: a respaced trajectory cannot "
            f"visit more distinct timesteps than the schedule has")
    ts = jnp.linspace(T - 1, 0, num_steps).round().astype(jnp.int32)
    return _strictly_decreasing(ts, num_steps)


def ancestral_coeffs(sched: NoiseSchedule, ts):
    """Per-step (ᾱ_t, ᾱ_prev) for the respaced trajectory."""
    ab_t = sched.alpha_bar[ts]
    ab_prev = jnp.concatenate([sched.alpha_bar[ts[1:]], jnp.ones((1,))])
    return ab_t, ab_prev


def _cfg_update(x, eps_c, eps_u, s, ab_t, ab_prev, noise, eta, use_pallas):
    if use_pallas:
        from repro.kernels.cfg_fuse import ops as cfg_ops
        return cfg_ops.cfg_update(x, eps_c, eps_u, s, ab_t, ab_prev, noise, eta)
    from repro.kernels.cfg_fuse import ref as cfg_ref
    return cfg_ref.cfg_update(x, eps_c, eps_u, s, ab_t, ab_prev, noise, eta)


class GuidanceStrategy:
    """How one reverse step turns x_t into the guided score pair."""

    def batch(self) -> int:
        raise NotImplementedError

    def prepare(self, params, dc: DiffusionConfig):
        return None

    def eps(self, params, dc: DiffusionConfig, x, t, ab_t, aux,
            use_pallas: bool = False):
        raise NotImplementedError


@dataclass(frozen=True)
class ClassifierFree(GuidanceStrategy):
    """Paper Eq. 8: ε̂ = (1+s)·ε_θ(x,t,ȳ) − s·ε_θ(x,t,Ø), both score
    evaluations batched into ONE denoiser call (cond/uncond stacked on
    batch — DESIGN.md §4)."""
    y: Any                      # (B, cond_dim) encodings ȳ
    scale: float

    def batch(self) -> int:
        return self.y.shape[0]

    def prepare(self, params, dc):
        B = self.y.shape[0]
        null = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
        return jnp.concatenate([self.y, null], axis=0)

    def eps(self, params, dc, x, t, ab_t, y2, use_pallas=False):
        B = x.shape[0]
        x2 = jnp.concatenate([x, x], axis=0)
        t2 = jnp.full((2 * B,), t, jnp.int32)
        eps2 = dit_apply(params, dc, x2, t2, y2, use_pallas=use_pallas)
        return eps2[:B], eps2[B:], self.scale


@dataclass(frozen=True)
class ClassifierGuided(GuidanceStrategy):
    """Paper Eq. 4 (FedCADO): unconditional score steered by the gradient
    of a client classifier's log p(y|x)."""
    logprob_fn: Callable        # (x, labels) -> (B,) log p(y|x)
    labels: Any                 # (B,) int32
    scale: float

    def batch(self) -> int:
        return self.labels.shape[0]

    def eps(self, params, dc, x, t, ab_t, aux, use_pallas=False):
        B = x.shape[0]
        tb = jnp.full((B,), t, jnp.int32)
        eps_u = dit_apply(params, dc, x, tb, None,      # unconditional score
                          use_pallas=use_pallas)
        sigma_t = jnp.sqrt(1.0 - ab_t)

        # classifier gradient taken at the x̂₀ prediction; the ∂x̂₀/∂x_t
        # chain factor 1/√ᾱ_t diverges at early steps (ᾱ→0) and destroys
        # samples, so the standard stabilisation is ∇_{x̂₀} directly with
        # per-sample normalisation (gradient direction, ε-scale magnitude).
        x0 = jnp.clip((x - jnp.sqrt(1 - ab_t) * eps_u) / jnp.sqrt(ab_t), -1, 1)
        labels = self.labels
        grad = jax.grad(lambda z: jnp.sum(self.logprob_fn(z, labels)))(x0)
        gnorm = jnp.sqrt(jnp.sum(grad ** 2, axis=(1, 2, 3), keepdims=True))
        grad = grad / jnp.maximum(gnorm, 1e-6)
        enorm = jnp.sqrt(jnp.mean(eps_u ** 2, axis=(1, 2, 3), keepdims=True))
        eps_hat = eps_u - self.scale * sigma_t * grad * enorm  # Eq. 4 (stab.)
        return eps_hat, None, 0.0


@dataclass(frozen=True)
class Unconditional(GuidanceStrategy):
    """Plain p(x) sampling through the null embedding Ø — the degenerate
    guidance point (FedDISC-style generation without a steering signal)."""
    num: int

    def batch(self) -> int:
        return self.num

    def eps(self, params, dc, x, t, ab_t, aux, use_pallas=False):
        B = x.shape[0]
        tb = jnp.full((B,), t, jnp.int32)
        return (dit_apply(params, dc, x, tb, None, use_pallas=use_pallas),
                None, 0.0)


def reverse_sample(params, dc: DiffusionConfig, sched: NoiseSchedule,
                   strategy: GuidanceStrategy, key, *,
                   image_size: int | None = None, channels: int = 3,
                   num_steps: int | None = None, eta: float = 1.0,
                   use_pallas: bool = False):
    """The one ancestral/DDIM loop (paper Eq. 9) shared by every strategy.

    x_T ~ N(0,I); for t in the respaced schedule the strategy produces the
    guided score pair and the fused update advances x_t → x_{t−1}.
    """
    B = strategy.batch()
    H = image_size or 16
    num_steps = num_steps or dc.sample_timesteps
    ts = respaced_ts(sched.T, num_steps)
    ab_t, ab_prev = ancestral_coeffs(sched, ts)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, (B, H, H, channels))
    aux = strategy.prepare(params, dc)

    def step(carry, inp):
        x, key = carry
        t, abt, abp = inp
        key, kn = jax.random.split(key)
        eps_c, eps_u, s = strategy.eps(params, dc, x, t, abt, aux,
                                       use_pallas=use_pallas)
        noise = jax.random.normal(kn, x.shape) * (t > 0)
        if eps_u is None:
            from repro.kernels.cfg_fuse import ref as cfg_ref
            x = cfg_ref.ancestral_step(x, eps_c, abt, abp, noise, eta)
        else:
            x = _cfg_update(x, eps_c, eps_u, s, abt, abp, noise, eta,
                            use_pallas)
        return (x, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), (ts, ab_t, ab_prev))
    return jnp.clip(x, -1.0, 1.0)


# ---------------------------------------------------------------------------
# ragged mode: per-row (guidance, steps) inside ONE compiled trajectory
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def _respaced_ts_host(T: int, k: int) -> np.ndarray:
    """Host-side memo of ``respaced_ts``: the (T, k) → trajectory mapping
    never changes, and table building runs in the packer's double-buffered
    window — a device dispatch + sync per wave there would eat the overlap
    the engine buys with async dispatch."""
    return np.asarray(respaced_ts(T, k), np.int32)


def ragged_tables(sched: NoiseSchedule, steps, max_steps: int):
    """Right-aligned per-row respacing tables for a ragged wave.

    Row ``b`` with ``steps[b] = k`` runs its k-step trajectory over the
    LAST k of ``max_steps`` scan iterations — every row finishes on the
    same final iteration, so the terminal clip stays shared — and is
    frozen before that by the active mask.  Each row's table slice is the
    row's own ``respaced_ts``/``ancestral_coeffs`` verbatim (built host-
    side per distinct step count), which is what makes a ragged row
    bit-exact against the same row sampled in a uniform wave.

    Returns ``(ts, ab_t, ab_prev, jloc)`` as (B, max_steps) numpy arrays;
    ``jloc[b, i] = i - (max_steps - k)`` is the row-local step index,
    negative while the row is frozen (``jloc >= 0`` is the active mask,
    and it keys the row's per-step noise stream so alignment padding
    never shifts a row's draws).  Frozen slots carry the row's first real
    (t, ᾱ) values — valid schedule positions, so the masked-out update
    lanes stay finite.
    """
    steps = np.asarray(steps, np.int32).reshape(-1)
    B, S = len(steps), int(max_steps)
    if steps.max(initial=1) > S:
        raise ValueError(f"max_steps={S} < largest row step count "
                         f"{int(steps.max())}")
    alpha_bar = np.asarray(sched.alpha_bar, np.float32)
    ts = np.zeros((B, S), np.int32)
    ab_t = np.zeros((B, S), np.float32)
    ab_prev = np.zeros((B, S), np.float32)
    jloc = np.arange(S, dtype=np.int32)[None] - (S - steps)[:, None]
    for k in np.unique(steps):
        rows = steps == k
        ts_k = _respaced_ts_host(sched.T, int(k))
        ab_k = alpha_bar[ts_k]
        abp_k = np.concatenate([ab_k[1:], np.ones((1,), np.float32)])
        ts[rows] = np.concatenate([np.full(S - k, ts_k[0], np.int32), ts_k])
        ab_t[rows] = np.concatenate([np.full(S - k, ab_k[0], np.float32),
                                     ab_k])
        ab_prev[rows] = np.concatenate([np.full(S - k, abp_k[0], np.float32),
                                        abp_k])
    return ts, ab_t, ab_prev, jloc


def _cfg_update_rowwise(x, eps_c, eps_u, s, ab_t, ab_prev, noise, active,
                        eta, use_pallas):
    if use_pallas:
        from repro.kernels.cfg_fuse import ops as cfg_ops
        return cfg_ops.cfg_update_rowwise(x, eps_c, eps_u, s, ab_t, ab_prev,
                                          noise, active, eta)
    from repro.kernels.cfg_fuse import ref as cfg_ref
    return cfg_ref.cfg_update_rowwise(x, eps_c, eps_u, s, ab_t, ab_prev,
                                      noise, active, eta)


def _ragged_scan(params, dc: DiffusionConfig, x, y2, row_keys, guidance,
                 ts, ab_t, ab_prev, jloc, *, eta: float, use_pallas: bool):
    """The shared per-row reverse scan: one iteration per table column,
    per-row (t, ᾱ_t, ᾱ_prev, guidance), per-row noise keyed
    ``fold_in(row_keys[b], 1 + j)`` with j the row-LOCAL step index, and
    an active mask (``jloc >= 0``) freezing rows whose right-aligned
    trajectory has not started.  Both the one-shot ragged wave and every
    compaction segment run THIS body, so their arithmetic is identical by
    construction — the substrate of the compacted/ragged bit-parity.
    Returns the advanced x UNCLIPPED (callers clip once, at the end of the
    full trajectory)."""
    B, H, _, channels = x.shape

    def step(x, inp):
        t, abt, abp, j = inp                     # (B,) each
        active = j >= 0
        x2 = jnp.concatenate([x, x], axis=0)
        t2 = jnp.concatenate([t, t])
        eps2 = dit_apply(params, dc, x2, t2, y2, use_pallas=use_pallas)
        eps_c, eps_u = eps2[:B], eps2[B:]
        nk = jax.vmap(jax.random.fold_in)(row_keys,
                                          jnp.maximum(j, 0) + 1)
        noise = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(nk)
        noise = noise * (t > 0)[:, None, None, None]
        x = _cfg_update_rowwise(x, eps_c, eps_u, guidance, abt, abp, noise,
                                active, eta, use_pallas)
        return x, None

    x, _ = jax.lax.scan(step, x,
                        (jnp.asarray(ts).T, jnp.asarray(ab_t).T,
                         jnp.asarray(ab_prev).T, jnp.asarray(jloc).T))
    return x


def reverse_sample_ragged(params, dc: DiffusionConfig, y, row_keys, guidance,
                          ts, ab_t, ab_prev, jloc, *, image_size: int,
                          channels: int = 3, eta: float = 1.0,
                          use_pallas: bool = False):
    """Classifier-free reverse loop with PER-ROW (guidance, steps).

    One compiled (B, max_steps) geometry serves rows from different
    classifier-free groups: each row carries its own guidance scale
    (``guidance`` (B,)), its own right-aligned respacing slice of the
    (B, S) tables from ``ragged_tables``, and its OWN noise stream —
    row ``b`` draws x_T from ``fold_in(row_keys[b], 0)`` and step-j noise
    from ``fold_in(row_keys[b], 1 + j)`` with j the row-LOCAL step index.
    Row-keyed noise is what makes the result independent of wave packing:
    a row produces bit-identical output whether its wave holds its own
    group, a mix of groups, or alignment padding.
    """
    B = y.shape[0]
    H = image_size
    kx = jax.vmap(lambda k: jax.random.fold_in(k, 0))(row_keys)
    x = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(kx)
    null = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
    y2 = jnp.concatenate([y, null], axis=0)
    guidance = jnp.asarray(guidance, jnp.float32)
    x = _ragged_scan(params, dc, x, y2, row_keys, guidance,
                     ts, ab_t, ab_prev, jloc, eta=eta, use_pallas=use_pallas)
    return jnp.clip(x, -1.0, 1.0)


# ---------------------------------------------------------------------------
# windowed mode: per-host row windows of a wave-resident scalar table
# ---------------------------------------------------------------------------
#
# Multi-host serving shards one merged wave into contiguous per-host windows
# (serve/topology.py::WavePlacement).  The wave's per-row (ᾱ_t, ᾱ_prev, s,
# active) scalars live in ONE wave-resident table; a host's scan updates
# only its window's rows and reads row b's scalars at wave slot
# ``row_offset + b`` through the segment-offset cfg_fuse path — no per-host
# sliced copy of the table per step.  Because row noise is keyed by request
# identity and the per-row arithmetic is independent across rows, a window
# scan is bit-exact against the same rows inside the full-wave ragged scan.


def _cfg_update_window(x, eps_c, eps_u, s, ab_t, ab_prev, noise, active,
                       row_offset, eta, use_pallas):
    if use_pallas:
        from repro.kernels.cfg_fuse import ops as cfg_ops
        return cfg_ops.cfg_update_rowwise(x, eps_c, eps_u, s, ab_t, ab_prev,
                                          noise, active, eta,
                                          row_offset=row_offset)
    from repro.kernels.cfg_fuse import ref as cfg_ref
    return cfg_ref.cfg_update_rowwise_windowed(x, eps_c, eps_u, s, ab_t,
                                               ab_prev, noise, active,
                                               row_offset=row_offset, eta=eta)


def _ragged_scan_window(params, dc: DiffusionConfig, x, y2, row_keys,
                        guidance, ts, jloc, ab_t, ab_prev, active, *,
                        row_offset: int, eta: float, use_pallas: bool):
    """The windowed per-row reverse scan: ``x`` holds only wave rows
    ``[row_offset, row_offset + Bw)``.  ``guidance`` (B,) and
    ``ab_t``/``ab_prev``/``active`` (B, S) span the FULL wave — the fused
    update reads tensor row b's scalars at wave slot ``row_offset + b``
    (``cfg_update_rowwise(row_offset=...)``) — while ``ts``/``jloc``
    (Bw, S) are window-local (only this window's rows feed the denoiser
    and the noise stream).  Per-row arithmetic is identical to
    ``_ragged_scan``; only which rows this launch updates changes, which
    is the substrate of the cross-topology bit-parity.  Returns x
    UNCLIPPED."""
    B, H, _, channels = x.shape

    def step(x, inp):
        t, j, abt, abp, act = inp         # t/j: (Bw,); abt/abp/act: (B,)
        x2 = jnp.concatenate([x, x], axis=0)
        t2 = jnp.concatenate([t, t])
        eps2 = dit_apply(params, dc, x2, t2, y2, use_pallas=use_pallas)
        eps_c, eps_u = eps2[:B], eps2[B:]
        nk = jax.vmap(jax.random.fold_in)(row_keys,
                                          jnp.maximum(j, 0) + 1)
        noise = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(nk)
        noise = noise * (t > 0)[:, None, None, None]
        x = _cfg_update_window(x, eps_c, eps_u, guidance, abt, abp, noise,
                               act, row_offset, eta, use_pallas)
        return x, None

    x, _ = jax.lax.scan(step, x,
                        (jnp.asarray(ts).T, jnp.asarray(jloc).T,
                         jnp.asarray(ab_t).T, jnp.asarray(ab_prev).T,
                         jnp.asarray(active).T))
    return x


def reverse_sample_window(params, dc: DiffusionConfig, x, y, row_keys,
                          guidance, ts, jloc, ab_t, ab_prev, active, *,
                          row_offset: int, image_size: int, channels: int = 3,
                          eta: float = 1.0, use_pallas: bool = False):
    """One segment of one host window: advance the carried rows, admit
    the new.  ``x`` is the previous segment's output (the first
    ``x.shape[0]`` rows of this segment); rows ``x.shape[0]:`` activate
    here — their x_T is drawn from ``fold_in(row_keys[b], 0)``, the same
    draw every other schedule makes for that row.  ``y``/``row_keys`` and
    the ``ts``/``jloc`` tables are window-local slices;
    ``guidance``/``ab_t``/``ab_prev``/``active`` span the full wave (see
    ``_ragged_scan_window``).  Returns x UNCLIPPED (the trajectory may
    continue into the next segment; the caller clips once at the end)."""
    n_prev = x.shape[0]
    H = image_size
    kx = jax.vmap(lambda k: jax.random.fold_in(k, 0))(row_keys[n_prev:])
    x_new = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(kx)
    x = jnp.concatenate([x, x_new], axis=0)
    B = x.shape[0]
    null = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
    y2 = jnp.concatenate([y, null], axis=0)
    return _ragged_scan_window(params, dc, x, y2, row_keys,
                               jnp.asarray(guidance, jnp.float32), ts, jloc,
                               ab_t, ab_prev, active, row_offset=row_offset,
                               eta=eta, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# mixed mode: cfg + classifier-guided + uncond rows in ONE ragged wave
# ---------------------------------------------------------------------------
#
# Every guidance strategy is the same ancestral loop differing only in how
# ε̂ is produced, so a wave can carry all three per ROW: ``mode`` (B,)
# selects the combine (0 = cfg pair-combine; uncond rides it as the s=0,
# null-cond degenerate point; 1 = classifier ε̂-correction), ``clf_ids``
# (B,) picks the row's classifier out of the wave's ensemble tuple, and
# ``labels`` (B,) feeds the classifiers.  The classifier correction is
# vectorised by evaluating each ensemble member's gradient over the FULL
# batch and selecting per row — heterogeneous ensembles need no lax.switch
# because the stack/select is itself shape-uniform.  Batching contract:
# a classifier's per-row log p(y|x) must depend only on that row (true for
# any per-sample net; batch-coupled ops like batchnorm would break the
# row-independence that makes packing invisible in D_syn).  Because each
# row's noise is keyed by request identity and all per-row arithmetic is
# row-independent, a mixed wave is bit-exact against the same rows drained
# in isolated single-mode waves — at any H, packing, or arrival order.


def _cfg_update_mixed(x, eps_c, eps_u, mode, s, ab_t, ab_prev, noise, active,
                      eta, use_pallas):
    if use_pallas:
        from repro.kernels.cfg_fuse import ops as cfg_ops
        return cfg_ops.cfg_update_mixed(x, eps_c, eps_u, mode, s, ab_t,
                                        ab_prev, noise, active, eta)
    from repro.kernels.cfg_fuse import ref as cfg_ref
    return cfg_ref.cfg_update_mixed(x, eps_c, eps_u, mode, s, ab_t, ab_prev,
                                    noise, active, eta)


def _cfg_update_mixed_window(x, eps_c, eps_u, mode, s, ab_t, ab_prev, noise,
                             active, row_offset, eta, use_pallas):
    if use_pallas:
        from repro.kernels.cfg_fuse import ops as cfg_ops
        return cfg_ops.cfg_update_mixed(x, eps_c, eps_u, mode, s, ab_t,
                                        ab_prev, noise, active, eta,
                                        row_offset=row_offset)
    from repro.kernels.cfg_fuse import ref as cfg_ref
    return cfg_ref.cfg_update_mixed_windowed(x, eps_c, eps_u, mode, s, ab_t,
                                             ab_prev, noise, active,
                                             row_offset=row_offset, eta=eta)


def _clf_correct(eps_c, eps_u, x, ab_t, scale, labels, clf_ids, clf_fns,
                 is_clf):
    """Row-wise classifier ε̂-correction (Eq. 4) over a mixed wave.

    Replaces ``eps_c`` on classifier rows with the stabilised FedCADO
    update — ∇ log p(y|x̂₀) with per-sample gradient normalisation and
    ε-scale magnitude, line-for-line the arithmetic of
    ``ClassifierGuided.eps`` — leaving every other row's ε_c untouched
    for the cfg combine.  Each ensemble member is evaluated over the
    full batch and rows select their own via ``clf_ids``; a member's
    per-row output depends only on that row (the batching contract), so
    the values match the isolated per-classifier evaluation bit-exactly.
    """
    B = x.shape[0]
    r = lambda v: jnp.asarray(v).reshape((-1,) + (1,) * (x.ndim - 1))
    ab = r(ab_t)
    sigma_t = jnp.sqrt(1.0 - ab)
    x0 = jnp.clip((x - jnp.sqrt(1 - ab) * eps_u) / jnp.sqrt(ab), -1, 1)
    enorm = jnp.sqrt(jnp.mean(eps_u ** 2, axis=(1, 2, 3), keepdims=True))
    hats = []
    for fn in clf_fns:
        grad = jax.grad(lambda z, f=fn: jnp.sum(f(z, labels)))(x0)
        gnorm = jnp.sqrt(jnp.sum(grad ** 2, axis=(1, 2, 3), keepdims=True))
        grad = grad / jnp.maximum(gnorm, 1e-6)
        hats.append(eps_u - r(scale) * sigma_t * grad * enorm)  # Eq. 4
    eps_hat = jnp.stack(hats)[jnp.asarray(clf_ids), jnp.arange(B)]
    return jnp.where(r(is_clf), eps_hat, eps_c)


def _mixed_scan(params, dc: DiffusionConfig, x, y2, row_keys, guidance, mode,
                clf_ids, labels, ts, ab_t, ab_prev, jloc, *, clf_fns,
                eta: float, use_pallas: bool):
    """The mixed-mode sibling of ``_ragged_scan``: same stacked 2B
    denoiser call, same identity-keyed noise stream, same active mask —
    plus the per-row classifier correction and the per-row-mode fused
    update.  Returns x UNCLIPPED."""
    B, H, _, channels = x.shape
    mode = jnp.asarray(mode, jnp.float32)
    is_clf = mode >= 0.5

    def step(x, inp):
        t, abt, abp, j = inp                     # (B,) each
        active = j >= 0
        x2 = jnp.concatenate([x, x], axis=0)
        t2 = jnp.concatenate([t, t])
        eps2 = dit_apply(params, dc, x2, t2, y2, use_pallas=use_pallas)
        eps_c, eps_u = eps2[:B], eps2[B:]
        if clf_fns:
            eps_c = _clf_correct(eps_c, eps_u, x, abt, guidance, labels,
                                 clf_ids, clf_fns, is_clf)
        nk = jax.vmap(jax.random.fold_in)(row_keys,
                                          jnp.maximum(j, 0) + 1)
        noise = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(nk)
        noise = noise * (t > 0)[:, None, None, None]
        x = _cfg_update_mixed(x, eps_c, eps_u, mode, guidance, abt, abp,
                              noise, active, eta, use_pallas)
        return x, None

    x, _ = jax.lax.scan(step, x,
                        (jnp.asarray(ts).T, jnp.asarray(ab_t).T,
                         jnp.asarray(ab_prev).T, jnp.asarray(jloc).T))
    return x


def reverse_sample_mixed(params, dc: DiffusionConfig, y, row_keys, guidance,
                         mode, clf_ids, labels, ts, ab_t, ab_prev, jloc, *,
                         clf_fns=(), image_size: int, channels: int = 3,
                         eta: float = 1.0, use_pallas: bool = False):
    """Mixed-guidance reverse loop: PER-ROW (mode, guidance, steps).

    ``y`` carries the row's conditioning — the category encoding for cfg
    rows, the null embedding Ø for classifier-guided and uncond rows
    (``dit_apply(y=None)`` broadcasts the same Ø, so the substitution is
    bit-invisible).  Row b draws x_T from ``fold_in(row_keys[b], 0)`` and
    step-j noise from ``fold_in(row_keys[b], 1 + j)`` exactly like the
    pure-cfg ragged wave, so a row's value is independent of which modes
    share its wave."""
    B = y.shape[0]
    H = image_size
    kx = jax.vmap(lambda k: jax.random.fold_in(k, 0))(row_keys)
    x = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(kx)
    null = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
    y2 = jnp.concatenate([y, null], axis=0)
    x = _mixed_scan(params, dc, x, y2, row_keys,
                    jnp.asarray(guidance, jnp.float32), mode, clf_ids,
                    labels, ts, ab_t, ab_prev, jloc, clf_fns=clf_fns,
                    eta=eta, use_pallas=use_pallas)
    return jnp.clip(x, -1.0, 1.0)


def _mixed_scan_window(params, dc: DiffusionConfig, x, y2, row_keys,
                       guidance, mode, clf_ids, labels, ts, jloc, ab_t,
                       ab_prev, active, *, clf_fns, row_offset: int,
                       eta: float, use_pallas: bool):
    """Windowed mixed scan: ``guidance``/``mode``/``ab_t``/``ab_prev``/
    ``active`` span the FULL wave (the fused update reads tensor row b at
    wave slot ``row_offset + b``); ``x``/``y2``/``row_keys``/``labels``/
    ``clf_ids`` and ``ts``/``jloc`` are window-local.  The classifier
    correction needs this window's per-row scalars, so it slices the
    wave-resident ``mode``/``guidance``/``ab_t`` by the (possibly traced)
    ``row_offset``.  Returns x UNCLIPPED."""
    B, H, _, channels = x.shape
    mode = jnp.asarray(mode, jnp.float32)
    guidance = jnp.asarray(guidance, jnp.float32)
    sl = lambda v: jax.lax.dynamic_slice_in_dim(v, row_offset, B, 0)
    is_clf_w = sl(mode) >= 0.5
    g_w = sl(guidance)

    def step(x, inp):
        t, j, abt, abp, act = inp         # t/j: (Bw,); abt/abp/act: (B,)
        x2 = jnp.concatenate([x, x], axis=0)
        t2 = jnp.concatenate([t, t])
        eps2 = dit_apply(params, dc, x2, t2, y2, use_pallas=use_pallas)
        eps_c, eps_u = eps2[:B], eps2[B:]
        if clf_fns:
            eps_c = _clf_correct(eps_c, eps_u, x, sl(abt), g_w, labels,
                                 clf_ids, clf_fns, is_clf_w)
        nk = jax.vmap(jax.random.fold_in)(row_keys,
                                          jnp.maximum(j, 0) + 1)
        noise = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(nk)
        noise = noise * (t > 0)[:, None, None, None]
        x = _cfg_update_mixed_window(x, eps_c, eps_u, mode, guidance, abt,
                                     abp, noise, act, row_offset, eta,
                                     use_pallas)
        return x, None

    x, _ = jax.lax.scan(step, x,
                        (jnp.asarray(ts).T, jnp.asarray(jloc).T,
                         jnp.asarray(ab_t).T, jnp.asarray(ab_prev).T,
                         jnp.asarray(active).T))
    return x


def reverse_sample_mixed_window(params, dc: DiffusionConfig, x, y, row_keys,
                                guidance, mode, clf_ids, labels, ts, jloc,
                                ab_t, ab_prev, active, *, clf_fns=(),
                                row_offset: int, image_size: int,
                                channels: int = 3, eta: float = 1.0,
                                use_pallas: bool = False):
    """One segment of one host window of a MIXED wave: advance the
    carried rows, admit the new (x_T from ``fold_in(row_keys[b], 0)``).
    Same window contract as ``reverse_sample_window`` plus the wave-
    resident ``mode`` table and window-local ``clf_ids``/``labels``.
    Returns x UNCLIPPED."""
    n_prev = x.shape[0]
    H = image_size
    kx = jax.vmap(lambda k: jax.random.fold_in(k, 0))(row_keys[n_prev:])
    x_new = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(kx)
    x = jnp.concatenate([x, x_new], axis=0)
    B = x.shape[0]
    null = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
    y2 = jnp.concatenate([y, null], axis=0)
    return _mixed_scan_window(params, dc, x, y2, row_keys,
                              jnp.asarray(guidance, jnp.float32), mode,
                              clf_ids, labels, ts, jloc, ab_t, ab_prev,
                              active, clf_fns=clf_fns, row_offset=row_offset,
                              eta=eta, use_pallas=use_pallas)


def reverse_sample_mixed_segment(params, dc: DiffusionConfig, x, y, row_keys,
                                 guidance, ts, ab_t, ab_prev, jloc, *,
                                 mode, clf_ids, labels, clf_fns=(),
                                 image_size: int, channels: int = 3,
                                 eta: float = 1.0, use_pallas: bool = False):
    """One compaction epoch of a MIXED wave: the mixed sibling of
    ``reverse_sample_segment`` (same admit-then-scan shape, same x_T
    draw), with the per-row mode/classifier operands riding along.
    Returns x UNCLIPPED."""
    n_prev = x.shape[0]
    H = image_size
    kx = jax.vmap(lambda k: jax.random.fold_in(k, 0))(row_keys[n_prev:])
    x_new = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(kx)
    x = jnp.concatenate([x, x_new], axis=0)
    B = x.shape[0]
    null = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
    y2 = jnp.concatenate([y, null], axis=0)
    return _mixed_scan(params, dc, x, y2, row_keys,
                       jnp.asarray(guidance, jnp.float32), mode, clf_ids,
                       labels, ts, ab_t, ab_prev, jloc, clf_fns=clf_fns,
                       eta=eta, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# compacted mode: iteration-compacted nested waves (compute-skipping ragged)
# ---------------------------------------------------------------------------
#
# The one-shot ragged scan runs EVERY row through all max_steps iterations;
# right-aligned rows whose trajectory has not started ride the denoiser
# frozen — pure discarded compute (the row_iters_scheduled vs _active gap).
# Compaction partitions the iteration axis into K ACTIVATION EPOCHS: rows
# are sorted by start iteration (host-side, stable), and each epoch runs
# one scan segment over only the rows live by that epoch's end — nested
# waves whose batch grows as rows activate.  Because every row's noise is
# keyed by its request identity (not wave position or iteration count),
# and a frozen iteration is the identity on x, running a row's trajectory
# in segments is BIT-EXACT vs the one-shot ragged scan.


def plan_epochs(steps, max_steps: int, *, compaction="full",
                granule: int = 1, geoms=None, compile_cost: int = 256):
    """Partition a ragged wave into activation epochs.

    ``steps`` (B,) per-row step counts, ``max_steps`` the wave's step
    ceiling.  Row b activates at iteration ``start = max_steps - steps[b]``
    of the right-aligned shared scan.  Returns ``(order, epochs)``:
    ``order`` (B,) sorts rows by activation (earliest first, stable, so
    the rows live in any epoch are a PREFIX of the sorted order), and
    ``epochs`` is a tuple of ``(rows, begin, end)`` — scan iterations
    ``[begin, end)`` run over the first ``rows`` sorted rows.  The first
    epoch begins at the earliest start, so iterations where NO row is
    live (a running step ceiling above the wave's deepest row) are
    skipped outright.

    ``compaction`` selects the boundary set:

    * ``"full"`` — an epoch boundary at every distinct start: no row ever
      rides frozen, total scheduled row-iterations equal the true sum of
      per-row steps;
    * an ``int`` K — at most K epochs: full boundaries merged greedily,
      always dropping the boundary whose removal adds the fewest frozen
      row-iterations;
    * ``"auto"`` — a boundary is kept when the frozen row-iterations it
      saves outweigh its compile cost: rows arriving at start u save
      ``count(u) * (u - epoch_begin)`` iterations, and the cut costs
      ``compile_cost`` row-iteration-equivalents unless the segment
      geometry ``(carried, rows, length)`` — granule-rounded, exactly the
      key a jitted segment executable specializes on — is already in
      ``geoms``, the caller's shape-bucket cache of compiled segment
      geometries, which makes a split free once its executable exists
      (e.g. the same wave shape recurring across drains).

    ``granule`` rounds each epoch's row count up (to keep segment batches
    divisible by a mesh's data axes); the extra rows are future arrivals
    admitted early — frozen by the active mask until their start, so the
    rounding never changes a row's value, only the schedule.
    """
    steps = np.asarray(steps, np.int32).reshape(-1)
    B, S = len(steps), int(max_steps)
    if B == 0:
        raise ValueError("plan_epochs: empty wave")
    if steps.min() < 1:
        raise ValueError(f"plan_epochs: step counts must be >= 1, got "
                         f"{int(steps.min())}")
    if steps.max() > S:
        raise ValueError(f"plan_epochs: max_steps={S} < largest row step "
                         f"count {int(steps.max())}")
    starts = S - steps
    order = np.argsort(starts, kind="stable")
    ss = starts[order]
    events = [(int(u), int(c)) for u, c in
              zip(*np.unique(ss, return_counts=True))]   # ascending starts

    def _rounded(rows):
        return min(-(-rows // granule) * granule, B) if granule > 1 else rows

    if compaction == "full":
        bounds = [u for u, _ in events]
    elif isinstance(compaction, int) and not isinstance(compaction, bool):
        if compaction < 1:
            raise ValueError(f"plan_epochs: K={compaction} < 1")
        bounds = [u for u, _ in events]
        while len(bounds) > compaction:
            # drop the boundary whose removal freezes the fewest row-iters:
            # arrivals in its epoch ride from the previous boundary instead
            costs = []
            for i in range(1, len(bounds)):
                hi = bounds[i + 1] if i + 1 < len(bounds) else S
                arriving = sum(c for u, c in events if bounds[i] <= u < hi)
                costs.append((arriving * (bounds[i] - bounds[i - 1]), i))
            bounds.pop(min(costs)[1])
    elif compaction == "auto":
        geoms = geoms or set()
        bounds = [events[0][0]]
        live = events[0][1]
        carried = 0        # rows the would-be segment inherits (= the
                           # previous closed segment's rounded row count)
        for u, c in events[1:]:
            length = u - bounds[-1]
            cut_cost = (0 if (carried, _rounded(live), length) in geoms
                        else int(compile_cost))
            if c * length >= cut_cost:
                bounds.append(u)
                carried = _rounded(live)
            live += c
    else:
        raise ValueError(f"plan_epochs: unknown compaction={compaction!r} "
                         f"(expected 'full', 'auto', or an int K)")

    epochs = []
    for i, b0 in enumerate(bounds):
        b1 = bounds[i + 1] if i + 1 < len(bounds) else S
        rows = _rounded(int(np.searchsorted(ss, b1, side="left")))  # start < b1
        epochs.append((rows, b0, b1))
    return order, tuple(epochs)


def reverse_sample_segment(params, dc: DiffusionConfig, x, y, row_keys,
                           guidance, ts, ab_t, ab_prev, jloc, *,
                           image_size: int, channels: int = 3,
                           eta: float = 1.0, use_pallas: bool = False):
    """One compaction epoch: advance the carried rows and admit the new.

    ``x`` is the previous segment's output (the first ``x.shape[0]`` rows
    of this segment); rows ``x.shape[0]:`` activate here — their x_T is
    drawn from ``fold_in(row_keys[b], 0)``, the SAME draw the one-shot
    ragged scan makes up front, so admitting a row late never changes its
    trajectory.  Tables are the ``[:rows, begin:end]`` slices of the
    wave's ``ragged_tables``.  Returns x UNCLIPPED (the trajectory
    continues into the next segment; ``reverse_sample_compacted`` clips
    once at the end)."""
    n_prev = x.shape[0]
    H = image_size
    kx = jax.vmap(lambda k: jax.random.fold_in(k, 0))(row_keys[n_prev:])
    x_new = jax.vmap(lambda k: jax.random.normal(k, (H, H, channels)))(kx)
    x = jnp.concatenate([x, x_new], axis=0)
    B = x.shape[0]
    null = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
    y2 = jnp.concatenate([y, null], axis=0)
    guidance = jnp.asarray(guidance, jnp.float32)
    return _ragged_scan(params, dc, x, y2, row_keys, guidance,
                        ts, ab_t, ab_prev, jloc, eta=eta,
                        use_pallas=use_pallas)


def reverse_sample_compacted(params, dc: DiffusionConfig, y, row_keys,
                             guidance, ts, ab_t, ab_prev, jloc, *,
                             epochs, order=None, image_size: int,
                             channels: int = 3, eta: float = 1.0,
                             use_pallas: bool = False, segment_fn=None,
                             mode=None, clf_ids=None, labels=None,
                             clf_fns=()):
    """Compute-skipping ragged reverse process: nested activation waves.

    Runs one scan segment per epoch from ``plan_epochs`` — each over only
    the rows live by that epoch's end — and stitches the segments back
    into REQUEST order (``order`` from ``plan_epochs``; pass ``None`` if
    inputs are already activation-sorted).  Bit-exact vs
    ``reverse_sample_ragged`` on the same tables: row noise is keyed by
    request identity (``row_keys``), frozen iterations are the identity
    on x, and every segment runs the same scan body — so skipping a
    frozen row's iterations cannot change any row's value.

    ``segment_fn`` defaults to ``reverse_sample_segment``; callers that
    want one compiled executable per segment geometry pass a jitted
    wrapper (``sampler._compacted_segment``).

    Passing ``mode`` (with ``clf_ids``/``labels``/``clf_fns``) selects
    the MIXED-guidance segment contract: the per-row mode/classifier
    operands are permuted and sliced alongside every other row vector
    and forwarded to ``segment_fn`` as keyword arguments (default
    ``reverse_sample_mixed_segment``)."""
    mixed = mode is not None
    if segment_fn is None:
        segment_fn = (reverse_sample_mixed_segment if mixed
                      else reverse_sample_segment)
    if mixed:
        mode = np.asarray(mode, np.float32).reshape(-1)
        clf_ids = np.asarray(
            clf_ids if clf_ids is not None else np.zeros_like(mode),
            np.int32).reshape(-1)
        labels = np.asarray(
            labels if labels is not None else np.zeros_like(mode),
            np.int32).reshape(-1)
    if order is not None:
        idx = np.asarray(order)
        y, row_keys = y[idx], row_keys[idx]
        guidance = jnp.asarray(guidance, jnp.float32)[idx]
        ts, ab_t = ts[idx], ab_t[idx]
        ab_prev, jloc = ab_prev[idx], jloc[idx]
        if mixed:
            mode, clf_ids, labels = mode[idx], clf_ids[idx], labels[idx]
    H = image_size
    n_total = y.shape[0]
    if not epochs:
        raise ValueError("reverse_sample_compacted: empty epoch plan")
    if epochs[-1][0] != n_total:
        raise ValueError(
            f"epochs cover {epochs[-1][0]} rows; wave has {n_total}")
    # a caller-supplied plan must have the shape plan_epochs guarantees —
    # contiguous non-empty segments with nondecreasing row counts that
    # run the tables to their final iteration; a gap or an early stop
    # would silently return half-denoised rows
    S = ts.shape[1]
    if epochs[0][1] < 0:
        raise ValueError(f"reverse_sample_compacted: epoch begins at "
                         f"iteration {epochs[0][1]} < 0")
    prev_end, prev_rows = epochs[0][1], 1
    for rows, begin, end in epochs:
        if begin != prev_end or end <= begin or not (prev_rows <= rows
                                                     <= n_total):
            raise ValueError(
                f"reverse_sample_compacted: malformed epoch "
                f"({rows}, {begin}, {end}) — epochs must be contiguous, "
                f"non-empty, with nondecreasing row counts")
        prev_end, prev_rows = end, rows
    if prev_end != S:
        raise ValueError(
            f"reverse_sample_compacted: epochs stop at iteration "
            f"{prev_end}; tables span {S}")
    # ...and every iteration a row is ACTIVE (jloc >= 0, monotone per
    # row) must be computed by an epoch that includes the row: rows a
    # plan skips — before the first epoch, or above an epoch's row count
    # — must be frozen there, or their scan starts mid-trajectory from
    # fresh x_T
    jl = np.asarray(jloc)
    b0 = epochs[0][1]
    if b0 > 0 and not (jl[:, b0 - 1] < 0).all():
        raise ValueError(
            f"reverse_sample_compacted: rows are active before the first "
            f"epoch (begin {b0}) — their leading iterations would be "
            f"skipped")
    for rows, begin, end in epochs:
        if rows < n_total and not (jl[rows:, end - 1] < 0).all():
            raise ValueError(
                f"reverse_sample_compacted: epoch ({rows}, {begin}, {end}) "
                f"excludes rows that are active within it")
    x = jnp.zeros((0, H, H, channels))
    for rows, begin, end in epochs:
        kw = dict(image_size=H, channels=channels, eta=eta,
                  use_pallas=use_pallas)
        if mixed:
            kw.update(mode=mode[:rows], clf_ids=clf_ids[:rows],
                      labels=labels[:rows], clf_fns=clf_fns)
        x = segment_fn(params, dc, x, y[:rows], row_keys[:rows],
                       guidance[:rows], ts[:rows, begin:end],
                       ab_t[:rows, begin:end], ab_prev[:rows, begin:end],
                       jloc[:rows, begin:end], **kw)
    x = jnp.clip(x, -1.0, 1.0)
    if order is not None:
        inv = np.empty_like(idx)
        inv[idx] = np.arange(len(idx))
        x = x[inv]
    return x
