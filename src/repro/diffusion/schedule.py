"""Noise schedules for DDPM (Ho et al. 2020) — Eq. 1 of the paper."""
from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp


class NoiseSchedule(NamedTuple):
    betas: jnp.ndarray            # (T,)
    alphas: jnp.ndarray           # (T,)
    alpha_bar: jnp.ndarray        # (T,) cumulative products
    sqrt_ab: jnp.ndarray          # sqrt(alpha_bar)
    sqrt_1mab: jnp.ndarray        # sqrt(1 - alpha_bar)

    @property
    def T(self) -> int:
        return self.betas.shape[0]


def make_schedule(T: int = 1000, kind: str = "cosine",
                  beta_start: float = 1e-4, beta_end: float = 0.02) -> NoiseSchedule:
    if kind == "linear":
        betas = jnp.linspace(beta_start, beta_end, T)
    elif kind == "cosine":  # Nichol & Dhariwal
        s = 0.008
        t = jnp.arange(T + 1) / T
        f = jnp.cos((t + s) / (1 + s) * math.pi / 2) ** 2
        alpha_bar = f / f[0]
        betas = jnp.clip(1 - alpha_bar[1:] / alpha_bar[:-1], 0, 0.999)
    else:
        raise ValueError(kind)
    alphas = 1.0 - betas
    alpha_bar = jnp.cumprod(alphas)
    return NoiseSchedule(betas, alphas, alpha_bar,
                         jnp.sqrt(alpha_bar), jnp.sqrt(1.0 - alpha_bar))


def q_sample(sched: NoiseSchedule, x0, t, noise):
    """Forward process (Eq. 1 marginal): x_t = √ᾱ_t x_0 + √(1-ᾱ_t) ε."""
    a = sched.sqrt_ab[t][..., None, None, None]
    b = sched.sqrt_1mab[t][..., None, None, None]
    return a * x0 + b * noise
