"""DDPM training (Eq. 3) with classifier-free conditioning dropout.

``pretrain_dm`` plays the role of Stable Diffusion's web-scale pre-training
(DESIGN.md §8): the DM is trained ONCE on a broad distribution (union of
all domains), then frozen; the FL experiments never update it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import dit_apply, init_dit
from repro.diffusion.schedule import NoiseSchedule, make_schedule, q_sample
from repro.optim import adamw, apply_updates, init_adamw


def diffusion_loss(params, dc: DiffusionConfig, sched: NoiseSchedule,
                   x0, y, key, y_group=None):
    """Eq. 3: E ||ε - ε_θ(x_t, t, y)||².  Conditioning is dropped with
    prob ``dc.cond_drop_prob`` (classifier-free training, Ho & Salimans).

    ``y_group`` (optional): the (renormalised) mean encoding of each
    sample's (category × domain) group.  With prob ``dc.group_cond_prob``
    the model is conditioned on the GROUP MEAN instead of the per-image
    encoding — this is exactly the ȳ_c statistic clients upload (Eq. 7),
    so the server-side conditional p(x | ȳ_c) is trained in-distribution.
    (Beyond-paper training detail; recorded in DESIGN.md §8.)"""
    B = x0.shape[0]
    kt, kn, kd, kg = jax.random.split(key, 4)
    t = jax.random.randint(kt, (B,), 0, sched.T)
    noise = jax.random.normal(kn, x0.shape)
    x_t = q_sample(sched, x0, t, noise)
    y_in = y
    if y_group is not None:
        use_g = jax.random.bernoulli(kg, dc.group_cond_prob, (B,))
        y_in = jnp.where(use_g[:, None], y_group, y_in)
    drop = jax.random.bernoulli(kd, dc.cond_drop_prob, (B,))
    y_in = jnp.where(drop[:, None], params["null_y"][None], y_in)
    eps = dit_apply(params, dc, x_t, t, y_in)
    return jnp.mean(jnp.square(eps - noise))


def make_dm_train_step(dc: DiffusionConfig, sched: NoiseSchedule):
    def step(params, opt, x0, y, y_group, key):
        loss, grads = jax.value_and_grad(diffusion_loss)(params, dc, sched,
                                                         x0, y, key, y_group)
        updates, opt = adamw(grads, opt, params, lr=dc.lr, weight_decay=0.0)
        return apply_updates(params, updates), opt, loss
    return jax.jit(step)


def pretrain_dm(key, dc: DiffusionConfig, images, conds, *,
                image_size: int, channels: int, steps: int | None = None,
                log_every: int = 0, groups=None):
    """Pre-train the classifier-free DM on (images, cond-encodings).

    images: (N,H,W,C) in [-1,1]; conds: (N, cond_dim); groups: optional
    (N,) int group ids (category × domain) enabling group-mean
    conditioning (see ``diffusion_loss``).
    Returns (params, schedule, losses)."""
    steps = steps or dc.pretrain_steps
    sched = make_schedule(dc.train_timesteps, dc.schedule)
    kinit, kloop = jax.random.split(key)
    params = init_dit(kinit, dc, image_size, channels)
    opt = init_adamw(params)
    step = make_dm_train_step(dc, sched)
    N = images.shape[0]
    conds = jnp.asarray(conds)
    if groups is not None:
        import numpy as np
        groups = np.asarray(groups)
        G = int(groups.max()) + 1
        gm = np.zeros((G, conds.shape[-1]), np.float32)
        np.add.at(gm, groups, np.asarray(conds))
        cnt = np.bincount(groups, minlength=G)[:, None].clip(1)
        gm = gm / cnt
        gm /= np.linalg.norm(gm, axis=-1, keepdims=True) + 1e-6
        group_conds = jnp.asarray(gm)[groups]          # (N, cond_dim)
    else:
        group_conds = conds
    losses = []
    for i in range(steps):
        kloop, kb, ks = jax.random.split(kloop, 3)
        idx = jax.random.randint(kb, (min(dc.batch_size, N),), 0, N)
        params, opt, loss = step(params, opt, images[idx], conds[idx],
                                 group_conds[idx], ks)
        if log_every and (i % log_every == 0 or i == steps - 1):
            losses.append((i, float(loss)))
            print(f"  [dm-pretrain] step {i:5d} loss {float(loss):.4f}", flush=True)
        else:
            losses.append((i, float(loss)))
    return params, sched, losses
