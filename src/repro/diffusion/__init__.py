from repro.diffusion.schedule import NoiseSchedule, make_schedule
from repro.diffusion.dit import dit_apply, init_dit
from repro.diffusion.ddpm import diffusion_loss, make_dm_train_step, pretrain_dm
from repro.diffusion.guidance import (ClassifierFree, ClassifierGuided,
                                      GuidanceStrategy, Unconditional,
                                      reverse_sample)
from repro.diffusion.sampler import (sample_cfg, sample_classifier_guided,
                                     sample_uncond)
