"""Public samplers — thin wrappers over the strategy-parameterised core.

``sample_cfg`` — classifier-FREE guidance (paper Eq. 8/9): OSCAR's server
uses the uploaded category encodings ȳ_c directly as conditioning.

``sample_classifier_guided`` — classifier guidance (Eq. 4), the mechanism
behind the FedCADO baseline: requires a trained classifier per client and
a gradient through it at every step.

``sample_uncond`` — unguided p(x) sampling through the null embedding.

All three build a ``GuidanceStrategy`` and defer to
``guidance.reverse_sample`` — one scan loop, one respacing, one fused
Pallas update for the whole repo.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.guidance import (ClassifierFree, ClassifierGuided,
                                      Unconditional, plan_epochs,
                                      ragged_tables, reverse_sample,
                                      reverse_sample_compacted,
                                      reverse_sample_mixed,
                                      reverse_sample_mixed_segment,
                                      reverse_sample_mixed_window,
                                      reverse_sample_ragged,
                                      reverse_sample_segment,
                                      reverse_sample_window)
from repro.diffusion.guidance import respaced_ts as _respaced_ts  # noqa: F401
from repro.diffusion.schedule import NoiseSchedule


@partial(jax.jit, static_argnames=("dc", "num_steps", "use_pallas", "eta",
                                   "image_size", "channels", "guidance"))
def sample_cfg(params, dc: DiffusionConfig, sched: NoiseSchedule, y, key, *,
               image_size: int | None = None, channels: int = 3,
               num_steps: int | None = None, guidance: float | None = None,
               eta: float = 1.0, use_pallas: bool = False):
    """Generate images conditioned on encodings ``y`` (B, cond_dim)."""
    s = dc.guidance_scale if guidance is None else guidance
    return reverse_sample(params, dc, sched, ClassifierFree(y=y, scale=s),
                          key, image_size=image_size, channels=channels,
                          num_steps=num_steps, eta=eta, use_pallas=use_pallas)


def sample_classifier_guided(params, dc: DiffusionConfig, sched: NoiseSchedule,
                             clf_logprob_fn, labels, key, *,
                             image_size: int | None = None, channels: int = 3,
                             num_steps: int | None = None,
                             guidance: float | None = None, eta: float = 1.0,
                             use_pallas: bool = False):
    """Classifier-guided sampling (Eq. 4) — the FedCADO mechanism.

    ``clf_logprob_fn(x, labels) -> (B,)`` log p(y|x); gradients are taken
    through the classifier at the x₀-prediction (standard stabilisation).
    Unjitted at top level (the classifier closure is not hashable); the
    inner scan still traces once.
    """
    s = dc.guidance_scale if guidance is None else guidance
    strat = ClassifierGuided(logprob_fn=clf_logprob_fn, labels=labels, scale=s)
    return reverse_sample(params, dc, sched, strat, key,
                          image_size=image_size, channels=channels,
                          num_steps=num_steps, eta=eta, use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("dc", "image_size", "channels", "eta",
                                   "use_pallas"))
def _ragged_core(params, dc, y, row_keys, guidance, ts, ab_t, ab_prev, jloc,
                 *, image_size, channels, eta, use_pallas):
    return reverse_sample_ragged(params, dc, y, row_keys, guidance,
                                 ts, ab_t, ab_prev, jloc,
                                 image_size=image_size, channels=channels,
                                 eta=eta, use_pallas=use_pallas)


def sample_cfg_ragged(params, dc: DiffusionConfig, sched: NoiseSchedule, y,
                      row_keys, guidance, num_steps, *,
                      max_steps: int | None = None,
                      image_size: int | None = None, channels: int = 3,
                      eta: float = 1.0, use_pallas: bool = False):
    """Ragged classifier-free wave: PER-ROW guidance scales and step
    counts inside one compiled trajectory.

    ``y`` (B, cond_dim), ``row_keys`` (B,) PRNG keys, ``guidance`` (B,)
    and ``num_steps`` (B,) — one entry per row.  ``num_steps`` must be
    host-concrete (the right-aligned respacing tables are built outside
    the jit); the compiled geometry is keyed only by (B, max_steps), so a
    mixed (guidance, steps) workload shares ONE executable as long as its
    wave shape and step ceiling agree.  Row results depend only on the
    row's own (encoding, guidance, steps, key) — not on max_steps, the
    wave's other rows, or padding — see ``reverse_sample_ragged``.
    """
    steps = np.asarray(num_steps, np.int32).reshape(-1)
    S = int(max_steps if max_steps is not None else steps.max())
    ts, ab_t, ab_prev, jloc = ragged_tables(sched, steps, S)
    return _ragged_core(params, dc, y, row_keys,
                        jax.numpy.asarray(guidance, jax.numpy.float32),
                        ts, ab_t, ab_prev, jloc,
                        image_size=image_size or 16, channels=channels,
                        eta=eta, use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("dc", "image_size", "channels", "eta",
                                   "use_pallas"))
def _compacted_segment(params, dc, x, y, row_keys, guidance, ts, ab_t,
                       ab_prev, jloc, *, image_size, channels, eta,
                       use_pallas):
    """One compaction epoch, jitted: the executable is keyed by the
    segment GEOMETRY — (carried rows, live rows, iterations) — not by the
    wave it came from, so two waves (or two drains) whose epochs share a
    geometry share one compile.  Table values are traced operands: the
    same (rows, length) segment at a different iteration offset reuses
    the executable."""
    return reverse_sample_segment(params, dc, x, y, row_keys, guidance,
                                  ts, ab_t, ab_prev, jloc,
                                  image_size=image_size, channels=channels,
                                  eta=eta, use_pallas=use_pallas)


def sample_cfg_compacted(params, dc: DiffusionConfig, sched: NoiseSchedule,
                         y, row_keys, guidance, num_steps, *,
                         max_steps: int | None = None, compaction="full",
                         plan=None, geoms=None, compile_cost: int = 256,
                         granule: int = 1, image_size: int | None = None,
                         channels: int = 3, eta: float = 1.0,
                         use_pallas: bool = False):
    """Compute-skipping ragged wave: iteration-compacted nested waves.

    Same per-row contract as ``sample_cfg_ragged`` — ``y`` (B, cond_dim),
    ``row_keys``/``guidance``/``num_steps`` one entry per row, results
    bit-identical to it (and to the row sampled in any other packing) —
    but the reverse process runs as one scan segment per activation
    epoch, so frozen right-aligned rows stop riding the denoiser: total
    scheduled row-iterations drop from B × max_steps toward the true sum
    of per-row steps.  ``compaction``/``geoms``/``compile_cost``/
    ``granule`` are forwarded to ``plan_epochs``; pass ``plan`` (its
    ``(order, epochs)`` result) to reuse a plan the caller already made
    for accounting.  Returns rows in REQUEST order.
    """
    steps = np.asarray(num_steps, np.int32).reshape(-1)
    S = int(max_steps if max_steps is not None else steps.max())
    if plan is None:
        plan = plan_epochs(steps, S, compaction=compaction, granule=granule,
                           geoms=geoms, compile_cost=compile_cost)
    order, epochs = plan
    ts, ab_t, ab_prev, jloc = ragged_tables(sched, steps, S)
    return reverse_sample_compacted(
        params, dc, jnp.asarray(y), jnp.asarray(row_keys),
        jnp.asarray(guidance, jnp.float32), ts, ab_t, ab_prev, jloc,
        epochs=epochs, order=order, image_size=image_size or 16,
        channels=channels, eta=eta, use_pallas=use_pallas,
        segment_fn=_compacted_segment)


@partial(jax.jit, static_argnames=("dc", "image_size", "channels", "eta",
                                   "use_pallas"))
def _window_segment(params, dc, x, y, row_keys, guidance, ts, jloc, ab_t,
                    ab_prev, active, *, row_offset, image_size, channels,
                    eta, use_pallas):
    """One host-window segment, jitted: the executable specializes on
    (wave width, carried rows, window rows, iterations) — the same window
    geometry recurring across waves, drains, or HOSTS reuses one compile.
    ``row_offset`` and the wave-resident scalar tables are traced
    operands: equal-quota hosts at different wave offsets share a single
    executable, so adding hosts does not multiply the compile bill."""
    return reverse_sample_window(params, dc, x, y, row_keys, guidance,
                                 ts, jloc, ab_t, ab_prev, active,
                                 row_offset=row_offset,
                                 image_size=image_size, channels=channels,
                                 eta=eta, use_pallas=use_pallas)


def sample_cfg_window(params, dc: DiffusionConfig, sched: NoiseSchedule,
                      y, row_keys, guidance, num_steps, *, row_offset: int,
                      window_rows: int | None = None,
                      max_steps: int | None = None,
                      image_size: int | None = None, channels: int = 3,
                      eta: float = 1.0, use_pallas: bool = False):
    """One host's window of a placed ragged wave.

    ``guidance`` (B,) and ``num_steps`` (B,) span the FULL merged wave —
    they are the wave-resident scalar table — while ``y`` and
    ``row_keys`` carry only the window's rows
    ``[row_offset, row_offset + window_rows)`` (a host never holds
    another host's conditioning).  The fused cfg update reads each tensor
    row's scalars out of the wave table at ``row_offset + b`` (the
    segment-offset ``cfg_fuse`` path).  Row results are bit-identical to
    the same rows inside ``sample_cfg_ragged`` over the whole wave — row
    noise is keyed per row, and the per-row arithmetic never crosses
    rows — which is what makes host count and placement invisible in
    D_syn.
    """
    steps = np.asarray(num_steps, np.int32).reshape(-1)
    S = int(max_steps if max_steps is not None else steps.max())
    Bw = int(window_rows if window_rows is not None else y.shape[0])
    if y.shape[0] != Bw or row_keys.shape[0] != Bw:
        raise ValueError(f"window carries {Bw} rows; y has {y.shape[0]} "
                         f"and row_keys {row_keys.shape[0]}")
    if row_offset < 0 or row_offset + Bw > len(steps):
        raise ValueError(f"window [{row_offset}, {row_offset + Bw}) is out "
                         f"of range for a {len(steps)}-row wave")
    ts, ab_t, ab_prev, jloc = ragged_tables(sched, steps, S)
    w = slice(row_offset, row_offset + Bw)
    x = _window_segment(params, dc,
                        jnp.zeros((0, image_size or 16, image_size or 16,
                                   channels)),
                        jnp.asarray(y), jnp.asarray(row_keys),
                        jnp.asarray(guidance, jnp.float32),
                        ts[w], jloc[w], ab_t, ab_prev, jloc >= 0,
                        row_offset=row_offset, image_size=image_size or 16,
                        channels=channels, eta=eta, use_pallas=use_pallas)
    return jnp.clip(x, -1.0, 1.0)


@partial(jax.jit, static_argnames=("dc", "clf_fns", "image_size", "channels",
                                   "eta", "use_pallas"))
def _mixed_core(params, dc, y, row_keys, guidance, mode, clf_ids, labels,
                ts, ab_t, ab_prev, jloc, *, clf_fns, image_size, channels,
                eta, use_pallas):
    return reverse_sample_mixed(params, dc, y, row_keys, guidance, mode,
                                clf_ids, labels, ts, ab_t, ab_prev, jloc,
                                clf_fns=clf_fns, image_size=image_size,
                                channels=channels, eta=eta,
                                use_pallas=use_pallas)


def sample_mixed(params, dc: DiffusionConfig, sched: NoiseSchedule, y,
                 row_keys, guidance, mode, clf_ids, labels, num_steps, *,
                 clf_fns=(), max_steps: int | None = None,
                 image_size: int | None = None, channels: int = 3,
                 eta: float = 1.0, use_pallas: bool = False):
    """MIXED ragged wave: per-row (mode, guidance, steps, classifier).

    The per-row contract of ``sample_cfg_ragged`` plus ``mode`` (B,)
    (0 = cfg / uncond-as-s=0, 1 = classifier-guided), ``clf_ids`` (B,)
    indices into the static ``clf_fns`` ensemble tuple, and ``labels``
    (B,) classifier targets.  The executable is keyed by (B, max_steps)
    and the ensemble tuple identity — NOT by which rows carry which mode
    — so one compile serves every mixed-tenant packing of a wave shape.
    """
    steps = np.asarray(num_steps, np.int32).reshape(-1)
    S = int(max_steps if max_steps is not None else steps.max())
    ts, ab_t, ab_prev, jloc = ragged_tables(sched, steps, S)
    return _mixed_core(params, dc, y, row_keys,
                       jnp.asarray(guidance, jnp.float32),
                       jnp.asarray(mode, jnp.float32),
                       jnp.asarray(clf_ids, jnp.int32),
                       jnp.asarray(labels, jnp.int32),
                       ts, ab_t, ab_prev, jloc, clf_fns=tuple(clf_fns),
                       image_size=image_size or 16, channels=channels,
                       eta=eta, use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("dc", "clf_fns", "image_size", "channels",
                                   "eta", "use_pallas"))
def _mixed_segment(params, dc, x, y, row_keys, guidance, ts, ab_t, ab_prev,
                   jloc, *, mode, clf_ids, labels, clf_fns, image_size,
                   channels, eta, use_pallas):
    """One MIXED compaction epoch, jitted: keyed by segment geometry plus
    the ensemble tuple identity, like ``_compacted_segment``."""
    return reverse_sample_mixed_segment(params, dc, x, y, row_keys, guidance,
                                        ts, ab_t, ab_prev, jloc, mode=mode,
                                        clf_ids=clf_ids, labels=labels,
                                        clf_fns=clf_fns,
                                        image_size=image_size,
                                        channels=channels, eta=eta,
                                        use_pallas=use_pallas)


def sample_mixed_compacted(params, dc: DiffusionConfig, sched: NoiseSchedule,
                           y, row_keys, guidance, mode, clf_ids, labels,
                           num_steps, *, clf_fns=(),
                           max_steps: int | None = None, compaction="full",
                           plan=None, geoms=None, compile_cost: int = 256,
                           granule: int = 1, image_size: int | None = None,
                           channels: int = 3, eta: float = 1.0,
                           use_pallas: bool = False):
    """Compacted MIXED wave: ``sample_cfg_compacted``'s nested activation
    epochs with the mixed per-row operands riding along — bit-identical
    to ``sample_mixed`` on the same rows."""
    steps = np.asarray(num_steps, np.int32).reshape(-1)
    S = int(max_steps if max_steps is not None else steps.max())
    if plan is None:
        plan = plan_epochs(steps, S, compaction=compaction, granule=granule,
                           geoms=geoms, compile_cost=compile_cost)
    order, epochs = plan
    ts, ab_t, ab_prev, jloc = ragged_tables(sched, steps, S)
    return reverse_sample_compacted(
        params, dc, jnp.asarray(y), jnp.asarray(row_keys),
        jnp.asarray(guidance, jnp.float32), ts, ab_t, ab_prev, jloc,
        epochs=epochs, order=order, image_size=image_size or 16,
        channels=channels, eta=eta, use_pallas=use_pallas,
        segment_fn=_mixed_segment, mode=mode, clf_ids=clf_ids,
        labels=labels, clf_fns=tuple(clf_fns))


@partial(jax.jit, static_argnames=("dc", "clf_fns", "image_size", "channels",
                                   "eta", "use_pallas"))
def _window_segment_mixed(params, dc, x, y, row_keys, guidance, ts, jloc,
                          ab_t, ab_prev, active, *, mode, clf_ids, labels,
                          clf_fns, row_offset, image_size, channels, eta,
                          use_pallas):
    """One MIXED host-window segment, jitted: same geometry keying as
    ``_window_segment`` (row_offset and the wave tables are traced), plus
    the static ensemble tuple."""
    return reverse_sample_mixed_window(params, dc, x, y, row_keys, guidance,
                                       mode, clf_ids, labels, ts, jloc,
                                       ab_t, ab_prev, active,
                                       clf_fns=clf_fns,
                                       row_offset=row_offset,
                                       image_size=image_size,
                                       channels=channels, eta=eta,
                                       use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("dc", "num", "num_steps", "eta",
                                   "image_size", "channels", "use_pallas"))
def sample_uncond(params, dc: DiffusionConfig, sched: NoiseSchedule,
                  num: int, key, *, image_size: int | None = None,
                  channels: int = 3, num_steps: int | None = None,
                  eta: float = 1.0, use_pallas: bool = False):
    """Unconditional sampling: ``num`` draws from the DM's p(x)."""
    return reverse_sample(params, dc, sched, Unconditional(num=num), key,
                          image_size=image_size, channels=channels,
                          num_steps=num_steps, eta=eta, use_pallas=use_pallas)
