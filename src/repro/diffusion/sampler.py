"""Reverse-process samplers.

``sample_cfg`` — classifier-FREE guidance (paper Eq. 8/9): OSCAR's server
uses the uploaded category encodings ȳ_c directly as conditioning; the two
score evaluations are batched into ONE denoiser call (cond/uncond stacked
on batch — DESIGN.md §4) and the guidance-combine + ancestral update is a
fused elementwise op (Pallas kernel ``kernels/cfg_fuse`` when enabled).

``sample_classifier_guided`` — classifier guidance (Eq. 4), the mechanism
behind the FedCADO baseline: requires a trained classifier per client and
a gradient through it at every step.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import dit_apply
from repro.diffusion.schedule import NoiseSchedule


def _respaced_ts(T: int, num_steps: int):
    return jnp.linspace(T - 1, 0, num_steps).round().astype(jnp.int32)


def _ancestral_coeffs(sched: NoiseSchedule, ts):
    """Per-step (ᾱ_t, ᾱ_prev) for the respaced trajectory."""
    ab_t = sched.alpha_bar[ts]
    ab_prev = jnp.concatenate([sched.alpha_bar[ts[1:]], jnp.ones((1,))])
    return ab_t, ab_prev


def _cfg_update(x, eps_c, eps_u, s, ab_t, ab_prev, noise, eta, use_pallas):
    if use_pallas:
        from repro.kernels.cfg_fuse import ops as cfg_ops
        return cfg_ops.cfg_update(x, eps_c, eps_u, s, ab_t, ab_prev, noise, eta)
    from repro.kernels.cfg_fuse import ref as cfg_ref
    return cfg_ref.cfg_update(x, eps_c, eps_u, s, ab_t, ab_prev, noise, eta)


@partial(jax.jit, static_argnames=("dc", "num_steps", "use_pallas", "eta",
                                   "image_size", "channels", "guidance"))
def sample_cfg(params, dc: DiffusionConfig, sched: NoiseSchedule, y, key, *,
               image_size: int | None = None, channels: int = 3,
               num_steps: int | None = None, guidance: float | None = None,
               eta: float = 1.0, use_pallas: bool = False):
    """Generate images conditioned on encodings ``y`` (B, cond_dim).

    x_T ~ N(0,I); for t in respaced schedule:
        ε̂ = (1+s)·ε_θ(x_t,t,ȳ) − s·ε_θ(x_t,t,Ø)          (Eq. 8)
        x_{t-1} = ancestral/DDIM step with noise σ_t·N(0,I)  (Eq. 9)
    """
    B = y.shape[0]
    H = image_size or 16
    s = dc.guidance_scale if guidance is None else guidance
    num_steps = num_steps or dc.sample_timesteps
    ts = _respaced_ts(sched.T, num_steps)
    ab_t, ab_prev = _ancestral_coeffs(sched, ts)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, (B, H, H, channels))
    null = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
    y2 = jnp.concatenate([y, null], axis=0)

    def step(carry, inp):
        x, key = carry
        t, abt, abp = inp
        key, kn = jax.random.split(key)
        # one batched denoiser call for the two score evaluations
        x2 = jnp.concatenate([x, x], axis=0)
        t2 = jnp.full((2 * B,), t, jnp.int32)
        eps2 = dit_apply(params, dc, x2, t2, y2)
        eps_c, eps_u = eps2[:B], eps2[B:]
        noise = jax.random.normal(kn, x.shape) * (t > 0)
        x = _cfg_update(x, eps_c, eps_u, s, abt, abp, noise, eta, use_pallas)
        return (x, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), (ts, ab_t, ab_prev))
    return jnp.clip(x, -1.0, 1.0)


def sample_classifier_guided(params, dc: DiffusionConfig, sched: NoiseSchedule,
                             clf_logprob_fn, labels, key, *,
                             image_size: int | None = None, channels: int = 3,
                             num_steps: int | None = None,
                             guidance: float | None = None, eta: float = 1.0):
    """Classifier-guided sampling (Eq. 4) — the FedCADO mechanism.

    ``clf_logprob_fn(x, labels) -> (B,)`` log p(y|x); gradients are taken
    through the classifier at the x₀-prediction (standard stabilisation).
    """
    B = labels.shape[0]
    H = image_size or 16
    s = dc.guidance_scale if guidance is None else guidance
    num_steps = num_steps or dc.sample_timesteps
    ts = _respaced_ts(sched.T, num_steps)
    ab_t, ab_prev = _ancestral_coeffs(sched, ts)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, (B, H, H, channels))

    def step(carry, inp):
        x, key = carry
        t, abt, abp = inp
        key, kn = jax.random.split(key)
        tb = jnp.full((B,), t, jnp.int32)
        eps_u = dit_apply(params, dc, x, tb, None)      # unconditional score
        sigma_t = jnp.sqrt(1.0 - abt)

        # classifier gradient taken at the x̂₀ prediction; the ∂x̂₀/∂x_t
        # chain factor 1/√ᾱ_t diverges at early steps (ᾱ→0) and destroys
        # samples, so the standard stabilisation is ∇_{x̂₀} directly with
        # per-sample normalisation (gradient direction, ε-scale magnitude).
        x0 = jnp.clip((x - jnp.sqrt(1 - abt) * eps_u) / jnp.sqrt(abt), -1, 1)
        grad = jax.grad(lambda z: jnp.sum(clf_logprob_fn(z, labels)))(x0)
        gnorm = jnp.sqrt(jnp.sum(grad ** 2, axis=(1, 2, 3), keepdims=True))
        grad = grad / jnp.maximum(gnorm, 1e-6)
        enorm = jnp.sqrt(jnp.mean(eps_u ** 2, axis=(1, 2, 3), keepdims=True))
        eps_hat = eps_u - s * sigma_t * grad * enorm     # Eq. 4 (stabilised)
        noise = jax.random.normal(kn, x.shape) * (t > 0)
        from repro.kernels.cfg_fuse import ref as cfg_ref
        x = cfg_ref.ancestral_step(x, eps_hat, abt, abp, noise, eta)
        return (x, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), (ts, ab_t, ab_prev))
    return jnp.clip(x, -1.0, 1.0)
