"""Conditional DiT denoiser ε_θ(x_t, t, y) — the in-repo stand-in for
Stable Diffusion (DESIGN.md §8).

TPU-native choice: pure matmul pipeline (patchify → adaLN-zero transformer
→ unpatchify), conditioned on a 512-d encoding vector (the CLIP-embedding
slot of the OSCAR pipeline) via adaLN modulation.  A learned null embedding
Ø implements classifier-free training/sampling (Ho & Salimans).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.oscar import DiffusionConfig
from repro.utils import lecun_init, normal_init, zeros_init


def timestep_embedding(t, dim: int, max_period: float = 10_000.0):
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half) / half)
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def init_dit(key, dc: DiffusionConfig, image_size: int, channels: int):
    d, p = dc.d_model, dc.patch
    n_tok = (image_size // p) ** 2
    patch_dim = p * p * channels
    ks = jax.random.split(key, 8 + 6 * dc.num_layers)
    params = {
        "patch_in": {"w": lecun_init(ks[0], (patch_dim, d)),
                     "b": zeros_init(ks[0], (d,))},
        "pos": normal_init(ks[1], (n_tok, d), stddev=0.02),
        "t_mlp1": {"w": lecun_init(ks[2], (d, d)), "b": zeros_init(ks[2], (d,))},
        "t_mlp2": {"w": lecun_init(ks[3], (d, d)), "b": zeros_init(ks[3], (d,))},
        "y_proj": {"w": lecun_init(ks[4], (dc.cond_dim, d)),
                   "b": zeros_init(ks[4], (d,))},
        "null_y": normal_init(ks[5], (dc.cond_dim,), stddev=0.5),
        "out_mod": {"w": zeros_init(ks[6], (d, 2 * d)), "b": zeros_init(ks[6], (2 * d,))},
        "patch_out": {"w": zeros_init(ks[7], (d, patch_dim)),
                      "b": zeros_init(ks[7], (patch_dim,))},
        # conditioning token: gives attention direct access to y (in
        # addition to adaLN modulation) — SD-style cross-attn analogue
        "cond_tok": {"w": lecun_init(jax.random.fold_in(key, 99), (dc.cond_dim, d)),
                     "b": zeros_init(ks[7], (d,))},
        "blocks": [],
    }
    blocks = []
    for i in range(dc.num_layers):
        k6 = ks[8 + 6 * i: 14 + 6 * i]
        blocks.append({
            "wqkv": {"w": lecun_init(k6[0], (d, 3 * d))},
            "wo": {"w": lecun_init(k6[1], (d, d))},
            "w_up": {"w": lecun_init(k6[2], (d, 4 * d)), "b": zeros_init(k6[2], (4 * d,))},
            "w_down": {"w": lecun_init(k6[3], (4 * d, d)), "b": zeros_init(k6[3], (d,))},
            # adaLN-zero: 6 modulation vectors, zero-init
            "mod": {"w": zeros_init(k6[4], (d, 6 * d)), "b": zeros_init(k6[4], (6 * d,))},
        })
    params["blocks"] = blocks
    return params


def _ln(x, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def _dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def _dense_act(p, x, bf16: bool):
    """_dense with optional bf16 activations/weights, fp32 accumulation."""
    if not bf16:
        return _dense(p, x)
    y = jax.lax.dot_general(
        x.astype(jnp.bfloat16), p["w"].astype(jnp.bfloat16),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"]
    return y


def _modulated_ln(x, scale, shift, fused: bool):
    """One DiT modulation site: LayerNorm + adaLN ``(1+scale)·x̂+shift``."""
    if fused:
        from repro.kernels.adaln_norm import ops as an_ops
        return an_ops.adaln_norm(x, scale, shift)
    return _ln(x) * (1 + scale[:, None]) + shift[:, None]


def patchify(x, p: int):
    B, H, W, C = x.shape
    x = x.reshape(B, H // p, p, W // p, p, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, (H // p) * (W // p), p * p * C)


def unpatchify(tok, p: int, H: int, W: int, C: int):
    B = tok.shape[0]
    x = tok.reshape(B, H // p, W // p, p, p, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, H, W, C)


def dit_apply(params, dc: DiffusionConfig, x_t, t, y, *,
              heads: int | None = None, use_pallas: bool = False):
    """ε-prediction.  x_t: (B,H,W,C); t: (B,) int32; y: (B, cond_dim) or
    None (→ null embedding Ø).

    ``use_pallas`` (or ``dc.use_pallas``) swaps the attention einsum chain
    for ``kernels.flash_attention`` (non-causal, S = n_tok+1) and the three
    LN+modulation sites for ``kernels.adaln_norm``; fp32 output matches the
    naive path within float tolerance.  ``dc.bf16_act`` additionally runs
    the QKV/MLP matmuls with bf16 activations + fp32 accumulation (fused
    path only).  The default path is untouched and stays bit-exact."""
    fused = use_pallas or getattr(dc, "use_pallas", False)
    bf16 = fused and getattr(dc, "bf16_act", False)
    B, H, W, C = x_t.shape
    p = dc.patch
    nh = heads or dc.num_heads
    tok = _dense(params["patch_in"], patchify(x_t, p)) + params["pos"]

    temb = timestep_embedding(t, dc.d_model)
    c = _dense(params["t_mlp2"], jax.nn.silu(_dense(params["t_mlp1"], temb)))
    if y is None:
        y = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
    c = c + _dense(params["y_proj"], y.astype(jnp.float32))
    c = jax.nn.silu(c)
    # prepend the conditioning token (sliced off before unpatchify)
    ytok = _dense(params["cond_tok"], y.astype(jnp.float32))[:, None, :]
    tok = jnp.concatenate([ytok, tok], axis=1)

    d = dc.d_model
    hd = d // nh
    for blk in params["blocks"]:
        mod = _dense(blk["mod"], c)                       # (B, 6d)
        sa_shift, sa_scale, sa_gate, ml_shift, ml_scale, ml_gate = jnp.split(mod, 6, -1)
        h = _modulated_ln(tok, sa_scale, sa_shift, fused)
        qkv = _dense_act(blk["wqkv"], h, bf16).reshape(B, -1, 3, nh, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        if fused:
            from repro.kernels.flash_attention import ops as fa_ops
            o = fa_ops.flash_attention(q, k, v, causal=False).reshape(B, -1, d)
        else:
            logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
            attn = jax.nn.softmax(logits, axis=-1)
            o = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(B, -1, d)
        tok = tok + sa_gate[:, None] * _dense_act(blk["wo"], o, bf16)
        h = _modulated_ln(tok, ml_scale, ml_shift, fused)
        h = _dense_act(blk["w_down"],
                       jax.nn.gelu(_dense_act(blk["w_up"], h, bf16)), bf16)
        tok = tok + ml_gate[:, None] * h

    tok = tok[:, 1:]   # drop the conditioning token
    shift, scale = jnp.split(_dense(params["out_mod"], c), 2, -1)
    tok = _modulated_ln(tok, scale, shift, fused)
    eps = _dense(params["patch_out"], tok)
    return unpatchify(eps, p, H, W, C)
