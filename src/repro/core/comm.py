"""Communication accounting — paper Table IV / Fig. 1.

``upload_params`` computes the per-client upload for OUR experiment scale;
``paper_scale_table4`` reproduces the paper's published numbers from its
constants (ResNet-18 = 11.69M params, 20 FedAvg rounds, C≈60 categories,
512-d CLIP encodings) to validate the accounting model itself.
"""
from __future__ import annotations

RESNET18_PARAMS = 11_689_512          # torchvision ResNet-18, the paper's unit
PAPER_FEDAVG_ROUNDS = 20
PAPER_ENC_DIM = 512


def upload_params(method: str, *, num_categories: int, enc_dim: int = 512,
                  clf_params: int = 0, rounds: int = 1,
                  n_prototypes: int = 4) -> int:
    """Parameters uploaded by EACH client for a full run of ``method``."""
    method = method.lower()
    if method == "local":
        return 0
    if method in ("fedavg", "fedprox", "feddyn"):
        return clf_params * rounds
    if method == "fedcado":
        return clf_params                       # one-shot classifier upload
    if method == "feddisc":
        return (2 + n_prototypes) * num_categories * enc_dim
    if method == "oscar":
        return num_categories * enc_dim         # C × 512 (paper §VI-d)
    raise ValueError(method)


def paper_scale_table4() -> dict:
    """Reproduce Table IV (params uploaded per client, in millions)."""
    C = 60
    vals = {
        "Local": 0.0,
        "FedAvg": RESNET18_PARAMS * PAPER_FEDAVG_ROUNDS / 1e6,
        "FedCADO": RESNET18_PARAMS / 1e6,
        "FedDISC": 4.23,   # published value; feature-stat upload at CLIP scale
        "OSCAR": C * PAPER_ENC_DIM / 1e6,
    }
    return vals


def reduction_vs_sota(oscar: float, baselines: dict) -> float:
    """OSCAR's claimed ≥99% upload reduction vs the best DM-assisted SOTA."""
    sota = min(v for k, v in baselines.items()
               if k.lower() in ("fedcado", "feddisc"))
    return 1.0 - oscar / sota
