"""Multi-round FL baselines: Local, FedAvg, FedProx, FedDyn.

Clients are simulated data-parallel: per-client local training is vmapped
over a leading client axis (DESIGN.md §4 — clients ARE data shards; the
FedAvg aggregation is a mean over that axis, i.e. a psum in the sharded
deployment).  All baselines share one local-SGD kernel parameterised by
the proximal/dynamic-regularisation terms:

  FedAvg  (McMahan et al.):  plain local SGD, server averages.
  FedProx (Li et al.):       + μ/2·||w − w_g||².
  FedDyn  (Acar et al.):     + linear correction −⟨h_r, w⟩ + α/2·||w − w_g||²,
                             h_r ← h_r − α(w_r − w_g); server subtracts the
                             running mean of h.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.classifier_train import evaluate_per_domain, train_classifier, xent
from repro.models.classifiers import init_classifier
from repro.optim import apply_updates, init_sgdm, sgdm


def _tree_mean(stacked):
    return jax.tree.map(lambda a: jnp.mean(a, axis=0), stacked)


def _tree_axpy(a, x, y):  # y + a*x
    return jax.tree.map(lambda xi, yi: yi + a * xi, x, y)


@partial(jax.jit, static_argnames=("name", "steps", "batch", "lr", "mu", "alpha"))
def _local_sgd(global_params, h_state, images, labels, key, *, name,
               steps=20, batch=32, lr=0.05, mu=0.0, alpha=0.0):
    """One client's local pass.  mu: FedProx proximal; alpha: FedDyn."""
    N = images.shape[0]
    opt = init_sgdm(global_params)

    def local_loss(params, xb, yb):
        loss = xent(params, name, xb, yb)
        if mu > 0:
            loss = loss + 0.5 * mu * sum(
                jnp.sum(jnp.square(p - g)) for p, g in
                zip(jax.tree.leaves(params), jax.tree.leaves(global_params)))
        if alpha > 0:
            lin = sum(jnp.sum(h * p) for h, p in
                      zip(jax.tree.leaves(h_state), jax.tree.leaves(params)))
            prox = 0.5 * alpha * sum(
                jnp.sum(jnp.square(p - g)) for p, g in
                zip(jax.tree.leaves(params), jax.tree.leaves(global_params)))
            loss = loss - lin + prox
        return loss

    def body(i, carry):
        params, opt = carry
        k = jax.random.fold_in(key, i)
        idx = jax.random.randint(k, (batch,), 0, N)
        _, grads = jax.value_and_grad(local_loss)(params, images[idx], labels[idx])
        updates, opt = sgdm(grads, opt, params, lr=lr, momentum=0.9,
                            weight_decay=1e-4)
        return apply_updates(params, updates), opt

    params, _ = jax.lax.fori_loop(0, steps, body, (global_params, opt))
    new_h = h_state
    if alpha > 0:
        new_h = jax.tree.map(lambda h, p, g: h - alpha * (p - g),
                             h_state, params, global_params)
    return params, new_h


def run_fl(key, data, *, name="resnet18", method="fedavg", rounds=10,
           local_steps=20, batch=32, lr=0.05, mu=0.1, alpha=0.1,
           eval_every=0, participation: float = 1.0):
    """Multi-round FL.  Returns (global_params, metrics, uploads_per_client).

    uploads_per_client: parameters uploaded by EACH client over the whole
    run (rounds × |w|) — the Table IV quantity.

    ``participation`` < 1 simulates client dropout/stragglers (paper §I
    motivation for one-shot FL): each round a Bernoulli(participation)
    subset of clients trains and is aggregated; everyone else is skipped."""
    R = data.client_images.shape[0]
    C = data.num_categories
    kinit, kloop = jax.random.split(key)
    global_params = init_classifier(kinit, name, C)
    n_params = sum(int(jnp.size(l)) for l in jax.tree.leaves(global_params))

    mu_eff = mu if method == "fedprox" else 0.0
    alpha_eff = alpha if method == "feddyn" else 0.0
    h = jax.tree.map(lambda p: jnp.zeros((R,) + p.shape, p.dtype), global_params)
    h_server = jax.tree.map(jnp.zeros_like, global_params)

    local = jax.vmap(
        partial(_local_sgd, name=name, steps=local_steps, batch=batch, lr=lr,
                mu=mu_eff, alpha=alpha_eff),
        in_axes=(None, 0, 0, 0, 0))

    imgs = jnp.asarray(data.client_images)
    labs = jnp.asarray(data.client_labels)
    history = []
    rng = np.random.default_rng(int(jax.random.randint(kinit, (), 0, 2**31 - 1)))
    total_uploads = 0
    for rnd in range(rounds):
        kloop, kr = jax.random.split(kloop)
        keys = jax.random.split(kr, R)
        if participation < 1.0:
            mask = rng.random(R) < participation
            if not mask.any():
                mask[rng.integers(0, R)] = True
        else:
            mask = np.ones(R, bool)
        total_uploads += int(mask.sum())
        locals_, h_new = local(global_params, h, imgs, labs, keys)
        # only participants contribute updates / FedDyn state
        w = jnp.asarray(mask, jnp.float32)
        wsum = float(mask.sum())
        h = jax.tree.map(lambda hn, ho: jnp.where(
            w.reshape((-1,) + (1,) * (hn.ndim - 1)) > 0, hn, ho), h_new, h)
        mean_w = jax.tree.map(
            lambda lw: jnp.tensordot(w, lw, axes=1) / wsum, locals_)
        if method == "feddyn":
            delta = jax.tree.map(lambda lw, g: jnp.mean(lw, 0) - g,
                                 locals_, global_params)
            h_server = jax.tree.map(lambda hs, d: hs - alpha_eff * d,
                                    h_server, delta)
            global_params = jax.tree.map(lambda m, hs: m - hs / alpha_eff,
                                         mean_w, h_server)
        else:
            global_params = mean_w
        if eval_every and (rnd + 1) % eval_every == 0:
            acc = evaluate_per_domain(global_params, name, data)["avg"]
            history.append((rnd + 1, acc))
    metrics = evaluate_per_domain(global_params, name, data)
    uploads = n_params * total_uploads // R   # avg per client
    return global_params, dict(metrics, history=history), uploads


def run_local_only(key, data, *, name="resnet18", steps=200, batch=32,
                   lr=0.05):
    """Per-client standalone training (the paper's 'Local' row): each
    client's model is evaluated on its own domain test set; 'avg' is the
    mean of those per-client accuracies.  Upload = 0."""
    R = data.client_images.shape[0]
    C = data.num_categories
    metrics = {}
    accs = []
    for r in range(R):
        kr = jax.random.fold_in(key, r)
        params = init_classifier(kr, name, C)
        params = train_classifier(params, name,
                                  jnp.asarray(data.client_images[r]),
                                  jnp.asarray(data.client_labels[r]), kr,
                                  steps=steps, batch=batch, lr=lr)
        from repro.core.classifier_train import evaluate
        xi, yi = data.client_test_set(r)
        acc = evaluate(params, name, xi, yi)
        metrics[f"client{r + 1}"] = acc
        accs.append(acc)
    metrics["avg"] = sum(accs) / len(accs)
    return None, metrics, 0
