"""DM-assisted OSFL baselines the paper compares against.

FedCADO (Yang et al. 2023): every client trains a FULL classifier on its
local data and uploads it (11.69M params for ResNet-18 in the paper; the
scaled analogue here).  The server runs CLASSIFIER-GUIDED sampling (Eq. 4)
— a gradient through the client classifier at every denoising step — to
synthesise per-category data, then trains the global model.

FedDISC (Yang et al. 2024): clients upload per-category feature statistics
(means + spreads + a few prototype features) of a frozen encoder; the
server re-samples encodings from those statistics and generates via the
(classifier-free) DM.  Upload ≈ 6 × C × 512 — bigger than OSCAR's C × 512,
far smaller than a classifier (the paper's 4.23M at its scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.oscar import OscarConfig
from repro.core.classifier_train import (evaluate_per_domain, fit_global,
                                         train_classifier)
from repro.encoders.foundation import FrozenFM, category_encodings
from repro.models.classifiers import (classifier_apply, classifier_param_count,
                                      init_classifier)
from repro.serve.service import SynthesisService
from repro.serve.synthesis import SynthesisEngine


def _service(service, engine, ocfg, dm_params, sched, *,
             ragged: bool = False, compaction: int | str | None = None,
             topology=None, hosts: int | None = None, tracer=None):
    """Every baseline's D_syn generation routes through a service.  An
    explicitly-passed engine beats a shared service (same precedence as
    ``oscar.synthesize``); otherwise the shared service, else a fresh
    engine.  ``ragged=True`` opts the chosen engine into ragged waves,
    ``compaction`` into iteration-compacted segments, ``topology``/
    ``hosts`` into multi-host placed drains (opt-in only — none of them
    ever forces a shared engine's mode back)."""
    if engine is not None:
        return SynthesisService(engine.opt_in(ragged=ragged,
                                              compaction=compaction,
                                              topology=topology,
                                              hosts=hosts, tracer=tracer))
    if service is not None:
        service.engine.opt_in(ragged=ragged, compaction=compaction,
                              topology=topology, hosts=hosts, tracer=tracer)
        return service
    return SynthesisService(SynthesisEngine(
        dm_params, ocfg.diffusion, sched, image_size=ocfg.data.image_size,
        channels=ocfg.data.channels, ragged=ragged, compaction=compaction,
        topology=topology, hosts=hosts, tracer=tracer))


def run_fedcado(key, ocfg: OscarConfig, data, dm_params, sched, *,
                classifier: str | None = None, samples_per_category=None,
                local_steps: int = 200,
                engine: SynthesisEngine | None = None,
                service: SynthesisService | None = None,
                ragged: bool = False,
                compaction: int | str | None = None,
                topology=None, hosts: int | None = None, tracer=None):
    classifier = classifier or ocfg.classifier
    k_samples = samples_per_category or ocfg.samples_per_category
    R = data.client_images.shape[0]
    C = data.num_categories
    key, kloop = jax.random.split(key)

    # --- client side: train + upload full classifiers ---
    client_params = []
    for r in range(R):
        kr = jax.random.fold_in(kloop, r)
        p = init_classifier(kr, classifier, C)
        p = train_classifier(p, classifier,
                             jnp.asarray(data.client_images[r]),
                             jnp.asarray(data.client_labels[r]), kr,
                             steps=local_steps)
        client_params.append(p)
    upload = classifier_param_count(client_params[0])

    # --- server side: classifier-guided generation (Eq. 4) via service ---
    # One request per (client, category); the engine packs each client's
    # requests (same uploaded classifier → same wave group) into uniform
    # waves, so every client shares one compiled trajectory shape.
    # (``ragged``/``compaction`` affect only classifier-FREE groups; they
    # are threaded so a FedCADO run next to cfg traffic leaves the shared
    # engine configured.)
    svc = _service(service, engine, ocfg, dm_params, sched, ragged=ragged,
                   compaction=compaction, topology=topology, hosts=hosts,
                   tracer=tracer)

    def make_logprob(pr):
        def logprob(x, labels):
            logits = classifier_apply(pr, classifier, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        return logprob

    fut_cat = []
    for r in range(R):
        logprob = make_logprob(client_params[r])
        for c in np.unique(np.asarray(data.client_labels[r])):
            fut = svc.submit_classifier_guided(logprob, int(c), k_samples,
                                               group=("fedcado", r))
            fut_cat.append((fut, int(c)))
    key, kgen = jax.random.split(key)
    syn_x = np.concatenate(svc.gather([f for f, _ in fut_cat], kgen))
    syn_y = np.concatenate([np.full((k_samples,), c, np.int32)
                            for _, c in fut_cat])

    key, kclf = jax.random.split(key)
    gp = fit_global(kclf, classifier, C, syn_x, syn_y,
                    steps=ocfg.classifier_steps, batch=ocfg.classifier_batch)
    metrics = evaluate_per_domain(gp, classifier, data)
    return gp, metrics, upload, (syn_x, syn_y)


def run_feddisc(key, ocfg: OscarConfig, data, dm_params, sched, fm: FrozenFM,
                *, classifier: str | None = None, samples_per_category=None,
                n_prototypes: int = 4,
                engine: SynthesisEngine | None = None,
                service: SynthesisService | None = None,
                ragged: bool = False,
                compaction: int | str | None = None,
                topology=None, hosts: int | None = None, tracer=None):
    classifier = classifier or ocfg.classifier
    k_samples = samples_per_category or ocfg.samples_per_category
    R = data.client_images.shape[0]
    C = data.num_categories
    D = ocfg.encoding_dim

    # --- client side: per-category feature statistics ---
    means = np.zeros((R, C, D), np.float32)
    stds = np.zeros((R, C, D), np.float32)
    present = np.zeros((R, C), bool)
    for r in range(R):
        z = np.asarray(fm(data.client_images[r]))
        y = np.asarray(data.client_labels[r])
        for c in range(C):
            m = y == c
            if m.sum() == 0:
                continue
            present[r, c] = True
            means[r, c] = z[m].mean(0)
            stds[r, c] = z[m].std(0) + 1e-4
    # mean + std + n_prototypes exemplar features per category
    upload = (2 + n_prototypes) * C * D

    # --- server side: resample encodings, generate with the CF-DM.
    # Each (client, category)'s resampled statistics go up as ONE 2-D
    # request — k_samples DISTINCT conditioning rows, a single cache/
    # store entry (the engine batches across clients and categories into
    # uniform waves either way; ``ragged=True`` lets those waves also mix
    # with other classifier-free traffic, e.g. OSCAR uploads at a
    # different guidance scale, in one compiled trajectory, and
    # ``compaction`` skips the frozen iterations of that mixing).
    svc = _service(service, engine, ocfg, dm_params, sched, ragged=ragged,
                   compaction=compaction, topology=topology, hosts=hosts,
                   tracer=tracer)
    rng = np.random.default_rng(0)
    futs, labels = [], []
    for r in range(R):
        for c in range(C):
            if not present[r, c]:
                continue
            eps = rng.normal(size=(k_samples, D)).astype(np.float32)
            smp = means[r, c] + 0.5 * stds[r, c] * eps
            smp /= np.linalg.norm(smp, axis=-1, keepdims=True) + 1e-6
            futs.append(svc.submit(smp, int(c)))
            labels.append(np.full((k_samples,), c, np.int32))
    labels = (np.concatenate(labels) if labels
              else np.zeros((0,), np.int32))
    key, kgen = jax.random.split(key)
    syn_x = (np.concatenate(svc.gather(futs, kgen)) if futs
             else np.zeros((0, ocfg.data.image_size, ocfg.data.image_size,
                            ocfg.data.channels), np.float32))

    key, kclf = jax.random.split(key)
    if len(syn_x) == 0:
        # all-absent present mask: no D_syn — broadcast the untrained init
        gp = init_classifier(kclf, classifier, C)
    else:
        gp = fit_global(kclf, classifier, C, syn_x, labels,
                        steps=ocfg.classifier_steps,
                        batch=ocfg.classifier_batch)
    metrics = evaluate_per_domain(gp, classifier, data)
    return gp, metrics, upload, (syn_x, labels)
