"""End-to-end experiment driver: one call = one paper table row/column.

The DM is pre-trained ONCE on the broad (union) distribution with frozen-FM
conditioning — playing Stable Diffusion's role — then reused frozen by
OSCAR / FedCADO / FedDISC, exactly as the paper reuses SD v1.5.
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import numpy as np

from repro.configs.oscar import OscarConfig
from repro.core import comm
from repro.core.dm_baselines import run_fedcado, run_feddisc
from repro.core.fl import run_fl, run_local_only
from repro.core.oscar import run_oscar
from repro.data.federated import make_federated_data
from repro.diffusion.ddpm import pretrain_dm
from repro.encoders.foundation import FrozenFM

ALL_METHODS = ("local", "fedavg", "fedprox", "feddyn", "fedcado", "feddisc",
               "oscar")


class Experiment:
    """Caches the dataset + pre-trained DM across method runs.  The frozen
    DM is also checkpointed to disk (keyed by config) so repeated benchmark
    invocations skip the pre-training, as the paper reuses frozen SD."""

    def __init__(self, ocfg: OscarConfig | None = None, *, verbose: bool = True,
                 pretrain_steps: int | None = None, cache_dir: str | None = None,
                 hosts: int | None = None, tracer=None):
        """``hosts=H`` places every DM-assisted method's D_syn drains over
        an H-host serving topology (simulated in-process; see
        ``serve/topology.py``) — D_syn is bit-identical to any other host
        count, so table rows do not depend on the serving layout.

        ``tracer`` (an ``obs/trace.py::Tracer``) records the shared
        service's drain timelines and per-request latencies; export with
        ``obs/export.py::write_trace``.  Tracing never changes D_syn."""
        self.ocfg = ocfg or OscarConfig()
        self.verbose = verbose
        key = jax.random.PRNGKey(self.ocfg.seed)
        self.key, kdm = jax.random.split(key)
        t0 = time.time()
        self.data = make_federated_data(self.ocfg.data)
        self.fm = FrozenFM(self.ocfg.encoding_dim)
        if self.data.pool_images is not None:
            # DM pre-trains on the broad pool (SD's web-scale analogue),
            # independent of what the clients hold (DESIGN.md §8)
            union_x = self.data.pool_images
            union_lab = self.data.pool_labels
            union_dom = self.data.pool_domains
        else:
            union_x = self.data.client_images.reshape(
                -1, *self.data.client_images.shape[2:])
            union_lab = self.data.client_labels.reshape(-1)
            union_dom = self.data.client_domains.reshape(-1)
        union_y = np.asarray(self.fm(union_x))
        if self.verbose:
            print(f"[exp] data ready ({union_x.shape[0]} train images) "
                  f"{time.time()-t0:.1f}s", flush=True)

        from pathlib import Path
        from repro.checkpoint import io as ckpt
        from repro.diffusion.dit import init_dit
        from repro.diffusion.schedule import make_schedule
        steps = pretrain_steps or self.ocfg.diffusion.pretrain_steps
        cache_dir = Path(cache_dir or
                         Path(__file__).resolve().parents[3] / "benchmarks"
                         / "results" / "dm_cache")
        import hashlib
        tag = "dm_" + hashlib.md5(
            repr((self.ocfg.data, self.ocfg.diffusion, steps)).encode()
        ).hexdigest()[:10]
        cpath = cache_dir / tag
        self.sched = make_schedule(self.ocfg.diffusion.train_timesteps,
                                   self.ocfg.diffusion.schedule)
        if ckpt.exists(cpath):
            template = init_dit(kdm, self.ocfg.diffusion,
                                self.ocfg.data.image_size,
                                self.ocfg.data.channels)
            self.dm_params = ckpt.load_pytree(template, cpath)
            self.dm_losses = []
            if self.verbose:
                print(f"[exp] frozen DM loaded from cache {tag}", flush=True)
        else:
            t0 = time.time()
            if self.verbose:
                print("[exp] pre-training DM...", flush=True)
            C = self.data.num_categories
            groups = union_dom.astype(np.int64) * C + union_lab
            self.dm_params, self.sched, self.dm_losses = pretrain_dm(
                kdm, self.ocfg.diffusion, union_x, union_y,
                image_size=self.ocfg.data.image_size,
                channels=self.ocfg.data.channels,
                steps=steps, log_every=200 if verbose else 0, groups=groups)
            ckpt.save_pytree(self.dm_params, cpath,
                             meta={"steps": steps, "tag": tag})
            if self.verbose:
                print(f"[exp] DM pre-trained in {time.time()-t0:.1f}s "
                      f"(cached as {tag})", flush=True)

        # One SynthesisService shared by every DM-assisted method: waves are
        # compiled once per shape across methods, repeated submissions of
        # the same (encoding, guidance, steps) — e.g. a samples-per-category
        # sweep — are served/topped-up from the engine cache, and the cache
        # spills to a persistent store keyed by the DM tag (a different DM
        # gets a different store root) so repeated benchmark invocations
        # skip synthesis entirely across processes.
        from repro.serve.service import SynthesisService
        from repro.serve.store import SynthesisStore
        from repro.serve.synthesis import SynthesisEngine
        self.engine = SynthesisEngine(self.dm_params, self.ocfg.diffusion,
                                      self.sched,
                                      image_size=self.ocfg.data.image_size,
                                      channels=self.ocfg.data.channels,
                                      hosts=hosts, tracer=tracer)
        # the store root folds in the experiment seed: D_syn depends on
        # the drain keys (derived from ocfg.seed), so two seeds sharing a
        # store would silently collapse to one sample
        self.service = SynthesisService(
            self.engine, key=jax.random.fold_in(self.key, 0xD5),
            store=SynthesisStore(
                cache_dir / f"{tag}_dsyn_s{self.ocfg.seed}"))
        self.tracer = self.engine.tracer

    def _clf_params(self, name):
        from repro.models.classifiers import (classifier_param_count,
                                              init_classifier)
        p = init_classifier(jax.random.PRNGKey(0), name,
                            self.data.num_categories)
        return classifier_param_count(p)

    def run(self, method: str, *, classifier: str = None, rounds: int = 10,
            samples_per_category: int | None = None, **kw) -> dict:
        """Returns {metrics..., upload_params, method}."""
        method = method.lower()
        classifier = classifier or self.ocfg.classifier
        import zlib
        key = jax.random.fold_in(self.key, zlib.crc32(method.encode()))
        t0 = time.time()
        if method == "local":
            _, metrics, upload = run_local_only(key, self.data, name=classifier)
        elif method in ("fedavg", "fedprox", "feddyn"):
            _, metrics, upload = run_fl(key, self.data, name=classifier,
                                        method=method, rounds=rounds, **kw)
        elif method == "fedcado":
            _, metrics, upload, _ = run_fedcado(
                key, self.ocfg, self.data, self.dm_params, self.sched,
                classifier=classifier,
                samples_per_category=samples_per_category,
                service=self.service)
        elif method == "feddisc":
            _, metrics, upload, _ = run_feddisc(
                key, self.ocfg, self.data, self.dm_params, self.sched,
                self.fm, classifier=classifier,
                samples_per_category=samples_per_category,
                service=self.service)
        elif method == "oscar":
            # synthesize() gives an explicitly-passed engine precedence
            # over the shared service
            res = run_oscar(key, self.ocfg, self.data, self.dm_params,
                            self.sched, self.fm, classifier=classifier,
                            samples_per_category=samples_per_category,
                            engine=kw.pop("engine", None),
                            service=kw.pop("service", self.service), **kw)
            metrics, upload = res.metrics, res.upload_per_client
        else:
            raise ValueError(method)
        out = dict(metrics)
        out["upload_params"] = upload
        out["method"] = method
        out["wall_s"] = round(time.time() - t0, 1)
        if self.verbose:
            print(f"[exp] {method:8s} avg={out['avg']*100:5.2f}% "
                  f"upload={upload/1e3:.1f}k params ({out['wall_s']}s)",
                  flush=True)
        return out
