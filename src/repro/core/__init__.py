"""The paper's contribution: OSCAR one-shot FL pipeline + the baseline zoo.

Layout:
  classifier_train — global/local classifier training + evaluation
  fl               — multi-round FL baselines (Local/FedAvg/FedProx/FedDyn)
  dm_baselines     — DM-assisted OSFL baselines (FedCADO, FedDISC)
  oscar            — OSCAR itself (Eq. 6-9 pipeline)
  comm             — per-client upload accounting (Table IV / Fig. 1)
"""
from repro.core.oscar import OscarResult, run_oscar
from repro.core.comm import upload_params
