"""OSCAR — One-Shot federated learning with ClAssifier-fRee diffusion
models (the paper's §IV pipeline, end to end):

  (1) each client encodes its images with the frozen FM (Eq. 6) and
      mean-pools per category (Eq. 7)                     [client side]
  (2) each client uploads its C × 512 category encodings  [ONE round]
  (3) the server runs classifier-free guided sampling (Eq. 8/9, s=7.5,
      T=50) to synthesise ``samples_per_category`` images per uploaded
      (client, category) encoding → D_syn of 10·|R|·C images
  (4) the server trains the global classifier on D_syn and broadcasts it.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.oscar import OscarConfig
from repro.core.classifier_train import evaluate_per_domain, fit_global
from repro.encoders.foundation import FrozenFM, category_encodings
from repro.models.classifiers import init_classifier
from repro.serve.service import SynthesisService
from repro.serve.synthesis import SynthesisEngine


@dataclass
class OscarResult:
    metrics: dict                 # avg + per-client test accuracy (Table I row)
    upload_per_client: int        # parameters uploaded by each client
    syn_images: np.ndarray
    syn_labels: np.ndarray
    encodings: np.ndarray         # (R, C, 512) what was uploaded
    global_params: object = None


def client_encodings(fm: FrozenFM, data):
    """Step (1)+(2): per-client per-category mean encodings."""
    R = data.client_images.shape[0]
    C = data.num_categories
    enc = np.zeros((R, C, fm.dim), np.float32)
    present = np.zeros((R, C), bool)
    for r in range(R):
        m, p = category_encodings(fm, data.client_images[r],
                                  jnp.asarray(data.client_labels[r]), C)
        enc[r] = np.asarray(m)
        present[r] = np.asarray(p)
    return enc, present


def synthesize(key, dm_params, dc, sched, encodings, present, k_samples: int,
               *, image_size: int, channels: int = 3, guidance=None,
               use_pallas: bool = False, engine: SynthesisEngine | None = None,
               service: SynthesisService | None = None, wave_size: int = 128,
               ragged: bool = False, compaction: int | str | None = None,
               topology=None, hosts: int | None = None, tracer=None):
    """Step (3): server-side D_syn generation.  Returns (images, labels).

    Synthesis is embarrassingly parallel over (client × category × sample);
    every (client, category) encoding becomes one SynthesisService request
    and the engine batches them into uniform CFG waves (DESIGN.md §4).
    A shared ``service`` (e.g. ``Experiment.service``) additionally serves
    repeats from its persistent D_syn store.  An all-absent ``present``
    mask degenerates to empty arrays.

    ``ragged=True`` opts the engine into ragged waves (per-row guidance
    and step counts — one compiled trajectory across classifier-free
    groups; see ``SynthesisEngine``); ``compaction`` (implies ragged)
    further runs those waves as iteration-compacted nested segments, same
    bits, fewer scheduled row-iterations; ``topology``/``hosts`` places
    drains over a multi-host topology (per-host ingress queues and wave
    windows — same bits again, any host count).  ``tracer`` (an
    ``obs/trace.py::Tracer``) records the drain timeline and per-request
    latencies without touching D_syn.  Opt-in only: they switch a shared
    engine ON but never force a shared engine's mode back."""
    R, C, dim = encodings.shape
    svc, eng = service, engine
    if eng is not None:
        svc = None        # an explicitly-passed engine beats a shared
                          # service (callers pass one to isolate caches)
    elif svc is not None:
        eng = svc.engine
    if eng is not None and use_pallas and not eng.use_pallas:
        svc = eng = None  # explicit Pallas request overrides a non-Pallas
                          # shared engine (dedicated engine, separate cache)
    if eng is None:
        eng = SynthesisEngine(dm_params, dc, sched, image_size=image_size,
                              channels=channels, use_pallas=use_pallas,
                              wave_size=wave_size, ragged=ragged,
                              compaction=compaction, topology=topology,
                              hosts=hosts, tracer=tracer)
    else:
        eng.opt_in(ragged=ragged, compaction=compaction, topology=topology,
                   hosts=hosts, tracer=tracer)
    if svc is None:
        svc = SynthesisService(eng)
    futs, cats = [], []
    for r in range(R):
        for c in range(C):
            if not present[r, c]:
                continue
            futs.append(svc.submit(encodings[r, c], c, k_samples,
                                   guidance=guidance))
            cats.append(c)
    if not futs:
        return (np.zeros((0, image_size, image_size, channels), np.float32),
                np.zeros((0,), np.int32))
    images = np.concatenate(svc.gather(futs, key))
    labels = np.concatenate([np.full((k_samples,), c, np.int32)
                             for c in cats])
    return images, labels


def run_oscar(key, ocfg: OscarConfig, data, dm_params, sched, fm: FrozenFM,
              *, classifier: str | None = None, samples_per_category=None,
              classifier_steps: int | None = None,
              guidance: float | None = None,
              use_pallas: bool = False,
              engine: SynthesisEngine | None = None,
              service: SynthesisService | None = None,
              ragged: bool = False,
              compaction: int | str | None = None,
              topology=None, hosts: int | None = None,
              tracer=None) -> OscarResult:
    classifier = classifier or ocfg.classifier
    k_samples = samples_per_category or ocfg.samples_per_category
    kenc, ksyn, kclf = jax.random.split(key, 3)

    enc, present = client_encodings(fm, data)
    syn_x, syn_y = synthesize(ksyn, dm_params, ocfg.diffusion, sched, enc,
                              present, k_samples,
                              image_size=ocfg.data.image_size,
                              channels=ocfg.data.channels,
                              guidance=guidance, use_pallas=use_pallas,
                              engine=engine, service=service, ragged=ragged,
                              compaction=compaction, topology=topology,
                              hosts=hosts, tracer=tracer)
    if len(syn_x) == 0:
        # degenerate round: no (client, category) present anywhere — no
        # D_syn, so the broadcast model is the untrained init
        gp = init_classifier(kclf, classifier, data.num_categories)
    else:
        gp = fit_global(kclf, classifier, data.num_categories, syn_x, syn_y,
                        steps=classifier_steps or ocfg.classifier_steps,
                        batch=ocfg.classifier_batch)
    metrics = evaluate_per_domain(gp, classifier, data)
    upload = data.num_categories * ocfg.encoding_dim   # C × 512 (Table IV)
    return OscarResult(metrics, upload, syn_x, syn_y, enc, gp)
