"""Classifier training/eval used by the server (global model) and by the
FL baselines (local models).  Pure-functional, jit/vmap friendly."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.classifiers import classifier_apply, init_classifier
from repro.optim import sgdm, apply_updates, init_sgdm


def xent(params, name, images, labels, *, l2: float = 0.0):
    logits = classifier_apply(params, name, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    if l2:
        loss = loss + l2 * sum(jnp.sum(jnp.square(w))
                               for w in jax.tree.leaves(params))
    return loss


@partial(jax.jit, static_argnames=("name", "steps", "batch", "lr", "momentum"))
def train_classifier(params, name, images, labels, key, *, steps: int = 300,
                     batch: int = 64, lr: float = 0.05, momentum: float = 0.9):
    """SGD training loop (lax.fori) on a fixed in-memory dataset."""
    opt = init_sgdm(params)
    N = images.shape[0]

    def body(i, carry):
        params, opt = carry
        k = jax.random.fold_in(key, i)
        idx = jax.random.randint(k, (batch,), 0, N)
        loss, grads = jax.value_and_grad(xent)(params, name, images[idx],
                                               labels[idx])
        updates, opt = sgdm(grads, opt, params, lr=lr, momentum=momentum,
                            weight_decay=1e-4)
        return apply_updates(params, updates), opt

    params, _ = jax.lax.fori_loop(0, steps, body, (params, opt))
    return params


@partial(jax.jit, static_argnames=("name",))
def predict(params, name, images):
    return jnp.argmax(classifier_apply(params, name, images), axis=-1)


def evaluate(params, name, images, labels, batch: int = 256) -> float:
    correct = 0
    N = len(images)
    for i in range(0, N, batch):
        pred = predict(params, name, jnp.asarray(images[i:i + batch]))
        correct += int(jnp.sum(pred == jnp.asarray(labels[i:i + batch])))
    return correct / max(N, 1)


def evaluate_per_domain(params, name, data) -> dict:
    """Global + per-client (=per-domain) test accuracy, Table I layout."""
    res = {"avg": evaluate(params, name, data.test_images, data.test_labels)}
    for r in range(data.num_domains):
        xi, yi = data.client_test_set(r)
        res[f"client{r + 1}"] = evaluate(params, name, xi, yi)
    return res


def fit_global(key, name, num_classes, images, labels, *, steps=400,
               batch=64, lr=0.05):
    """Init + train + return params (server-side global model training)."""
    params = init_classifier(key, name, num_classes)
    return train_classifier(params, name, jnp.asarray(images),
                            jnp.asarray(labels), key, steps=steps,
                            batch=batch, lr=lr)
