"""Phi-3.5-MoE (42B total / 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8), vocab=32064; every FFN is MoE:
16 experts, top-2, expert d_ff=6400, SwiGLU experts.
Routing simplification: softmax top-k with renormalised gates stands in for
sparsemixer-v2 (DESIGN.md §8).  Full attention → ``long_500k`` skipped.
"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=32064,
    layer_pattern=(ATTN,),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400),
    gated_mlp=True,
    mlp_act="silu",
    remat="full",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
))
