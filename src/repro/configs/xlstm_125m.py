"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM blocks, attention-free.

12 blocks, d_model=768, 4 heads, vocab=50304, d_ff=0 (the xLSTM blocks
carry their own up/down projections).  Alternating (mLSTM, sLSTM) period.
O(1) recurrent decode state → ``long_500k`` runs natively.
"""
from repro.configs.base import MLSTM, SLSTM, ModelConfig, XLSTMConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=(MLSTM, SLSTM),
    xlstm=XLSTMConfig(),
    tie_embeddings=True,
    remat="none",
    source="arXiv:2405.04517",
))
