"""Config registry: importing this package registers every assigned arch.

Assigned pool (10 archs × 6 families) — see each module for the citation.
"""
from repro.configs.base import (INPUT_SHAPES, InputShape, ModelConfig,
                                MoEConfig, MambaConfig, XLSTMConfig,
                                get_config, list_configs, register)

# Architecture registration (order matches the assignment table).
from repro.configs import hubert_xlarge      # noqa: F401
from repro.configs import granite_20b        # noqa: F401
from repro.configs import gemma2_2b          # noqa: F401
from repro.configs import phi35_moe          # noqa: F401
from repro.configs import xlstm_125m         # noqa: F401
from repro.configs import internvl2_1b       # noqa: F401
from repro.configs import qwen2_7b           # noqa: F401
from repro.configs import olmoe_1b_7b        # noqa: F401
from repro.configs import qwen3_32b          # noqa: F401
from repro.configs import jamba_15_large     # noqa: F401
from repro.configs import oscar              # noqa: F401

from repro.configs.shapes import input_specs, smoke_config  # noqa: F401

ARCH_IDS = [
    "hubert-xlarge", "granite-20b", "gemma2-2b", "phi3.5-moe-42b-a6.6b",
    "xlstm-125m", "internvl2-1b", "qwen2-7b", "olmoe-1b-7b", "qwen3-32b",
    "jamba-1.5-large-398b",
]
