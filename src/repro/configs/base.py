"""Config dataclasses for the model zoo and input shapes.

Every assigned architecture file in this package builds a ``ModelConfig``
with the exact published hyper-parameters and registers it under its id.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp

# Layer kinds used in ``layer_pattern`` (the repeating period of the stack).
ATTN = "attn"          # full (global) self-attention
ATTN_LOCAL = "attn_local"  # sliding-window self-attention
MAMBA = "mamba"        # Mamba-1 selective SSM block
MLSTM = "mlstm"        # xLSTM matrix-memory block
SLSTM = "slstm"        # xLSTM scalar-memory block


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # Which layers inside the repeating period use MoE FFN (None = all).
    every_n: int = 1           # layer i uses MoE iff i % every_n == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance auxiliary loss weight


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0      # mLSTM block up-projection factor
    slstm_proj_factor: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                      # dense FFN hidden (0 = no separate FFN)
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- attention flavour ---
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0      # 0 = off (gemma2: 50.0)
    final_softcap: float = 0.0     # 0 = off (gemma2: 30.0)
    sliding_window: int = 0        # 0 = off
    rope_theta: float = 10_000.0
    # --- stack layout ---
    layer_pattern: tuple[str, ...] = (ATTN,)   # repeats to num_layers
    is_encoder: bool = False       # bidirectional, no decode step
    post_norms: bool = False       # gemma2-style post-sublayer norms
    # --- FFN flavour ---
    gated_mlp: bool = True         # SwiGLU/GeGLU vs plain GELU
    mlp_act: str = "silu"          # silu | gelu
    # --- sub-configs ---
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # --- embedding / head ---
    tie_embeddings: bool = False
    scale_embed: bool = False      # multiply embeddings by sqrt(d) (gemma)
    # --- frontend (audio/vlm carve-out stubs) ---
    frontend: str = "token"        # token | audio_frames | vision_patches
    frontend_dim: int = 0          # embedding dim produced by the stub
    num_prefix_tokens: int = 0     # vlm: image tokens prepended to text
    # --- misc ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"        # activation dtype
    remat: str = "none"            # none | full | dots  (checkpoint policy)
    source: str = ""               # citation

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        assert self.num_layers % len(self.layer_pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} must be a multiple of "
            f"the layer period {len(self.layer_pattern)}")

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab padded to a 256 multiple so the
        vocab-parallel sharding divides evenly (MaxText-style padding;
        labels always index < vocab_size)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_groups(self) -> int:
        return self.num_layers // self.period

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % self.period]

    def uses_moe(self, i: int) -> bool:
        m = self.moe
        return m is not None and (i % m.every_n) == m.moe_offset

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (analytic; used for roofline MODEL_FLOPS) ----
    def param_counts(self) -> dict:
        """Returns dict with total and active (per-token) parameter counts."""
        d, hd = self.d_model, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings and not self.is_encoder:
            total += self.vocab_size * d
        if self.frontend != "token" and self.frontend_dim:
            total += self.frontend_dim * d
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            t = 0
            if kind in (ATTN, ATTN_LOCAL):
                t += d * nq * hd + 2 * d * nkv * hd + nq * hd * d
                if self.qkv_bias:
                    t += (nq + 2 * nkv) * hd
            elif kind == MAMBA:
                mc = self.mamba or MambaConfig()
                din = mc.expand * d
                dtr = mc.dt_rank or -(-d // 16)
                t += d * 2 * din + din * mc.d_conv + din * (dtr + 2 * mc.d_state)
                t += dtr * din + din * mc.d_state + din + din * d
            elif kind == MLSTM:
                xc = self.xlstm or XLSTMConfig()
                din = int(xc.proj_factor * d)
                t += d * 2 * din + 3 * din * din // max(self.num_heads, 1) + 3 * din + din * d + din * xc.conv_kernel
            elif kind == SLSTM:
                xc = self.xlstm or XLSTMConfig()
                din = int(xc.slstm_proj_factor * d)
                t += 4 * d * d + 4 * d * d // max(self.num_heads, 1) + 4 * d
                t += d * 2 * din + din * d
            # FFN
            if self.uses_moe(i):
                m = self.moe
                per_expert = (3 if self.gated_mlp else 2) * d * m.d_ff_expert
                t += m.num_experts * per_expert + d * m.num_experts
            elif self.d_ff:
                t += (3 if self.gated_mlp else 2) * d * self.d_ff
            total += t
        return {"total": total, "active": self._active_params()}

    def _active_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        active = self.vocab_size * d
        if not self.tie_embeddings and not self.is_encoder:
            active += self.vocab_size * d
        if self.frontend != "token" and self.frontend_dim:
            active += self.frontend_dim * d
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            a = 0
            if kind in (ATTN, ATTN_LOCAL):
                a += d * nq * hd + 2 * d * nkv * hd + nq * hd * d
            elif kind == MAMBA:
                mc = self.mamba or MambaConfig()
                din = mc.expand * d
                dtr = mc.dt_rank or -(-d // 16)
                a += d * 2 * din + din * mc.d_conv + din * (dtr + 2 * mc.d_state)
                a += dtr * din + din * mc.d_state + din + din * d
            elif kind == MLSTM:
                xc = self.xlstm or XLSTMConfig()
                din = int(xc.proj_factor * d)
                a += d * 2 * din + 3 * din * din // max(self.num_heads, 1) + 3 * din + din * d
            elif kind == SLSTM:
                xc = self.xlstm or XLSTMConfig()
                din = int(xc.slstm_proj_factor * d)
                a += 4 * d * d + 4 * d * d // max(self.num_heads, 1)
                a += d * 2 * din + din * d
            if self.uses_moe(i):
                m = self.moe
                a += m.top_k * (3 if self.gated_mlp else 2) * d * m.d_ff_expert
                a += d * m.num_experts
            elif self.d_ff:
                a += (3 if self.gated_mlp else 2) * d * self.d_ff
            active += a
        return active


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs  # noqa: F401  (triggers registration imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs  # noqa: F401
    return sorted(_REGISTRY)
