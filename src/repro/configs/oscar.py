"""OSCAR experiment configuration — the paper's own hyper-parameters.

Paper settings (Sections IV–V): guidance scale s=7.5, T=50 sampling steps,
10 images generated per (client, category) by default (Table III sweeps
10..50), 6 clients (= #domains), 30 images/category/client for Table I,
ResNet-18 global classifier, single communication round, 512-d CLIP
encodings (so each client uploads C × 512 floats).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class DataConfig:
    num_categories: int = 10          # paper: 60 (NICO++) / 90 / 120; scaled
    num_domains: int = 6              # paper: 6 → one domain per client
    image_size: int = 16              # paper: 224; scaled for CPU (DESIGN §8)
    channels: int = 3
    train_per_cat_dom: int = 30       # images per (category, domain) train
    test_per_cat_dom: int = 8
    # Size of the DM pre-training pool per (category, domain) — disjoint
    # from client data.  0 = pre-train on the union of client shards.
    # Nonzero emulates the paper's asymmetry: Stable Diffusion's knowledge
    # is independent of (and far larger than) any client's local dataset.
    pretrain_pool_per_cat_dom: int = 0
    seed: int = 0


@dataclass(frozen=True)
class DiffusionConfig:
    # DiT denoiser (stands in for Stable Diffusion, DESIGN.md §8)
    d_model: int = 128
    num_layers: int = 4
    num_heads: int = 4
    patch: int = 4
    cond_dim: int = 512               # CLIP text-encoding dim (paper: 512)
    train_timesteps: int = 1000
    sample_timesteps: int = 50        # paper: T = 50
    # The paper fixes s=7.5 for Stable Diffusion.  Our scaled-down DM
    # saturates at that strength (validated in benchmarks/guidance sweep);
    # s=2.0 is the tuned equivalent.  The bench reports both.
    guidance_scale: float = 2.0
    paper_guidance_scale: float = 7.5
    cond_drop_prob: float = 0.1       # classifier-free training drop (Ho & Salimans)
    group_cond_prob: float = 0.4      # train on ȳ group means (DESIGN §8)
    pretrain_steps: int = 2500
    batch_size: int = 128
    lr: float = 3e-4
    schedule: str = "cosine"
    # Fused denoiser (opt-in): route dit_apply's attention through the
    # Pallas flash-attention kernel and its three LN+modulation sites
    # through kernels/adaln_norm.  fp32 fused output matches the naive
    # denoiser within float tolerance (online softmax reorders sums);
    # the default (False) path stays bit-exact with prior releases.
    use_pallas: bool = False
    # Under the fused path only: run the QKV/MLP matmuls with bf16
    # activations and fp32 accumulation (MXU-native mixed precision).
    bf16_act: bool = False


@dataclass(frozen=True)
class OscarConfig:
    data: DataConfig = field(default_factory=DataConfig)
    diffusion: DiffusionConfig = field(default_factory=DiffusionConfig)
    num_clients: int = 6              # paper: 6
    encoding_dim: int = 512           # paper: 512 params per category
    samples_per_category: int = 10    # paper: 10 (Table III sweeps)
    classifier: str = "resnet18"      # paper main results
    classifier_steps: int = 400
    classifier_lr: float = 1e-3
    classifier_batch: int = 64
    seed: int = 0
