"""InternVL2-1B [arXiv:2404.16821] — VLM: InternViT frontend + LM decoder.

LM backbone: 24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864,
vocab=151655, QKV bias, SwiGLU.

Frontend carve-out: the InternViT-300M vision tower + MLP projector are a
stub — ``input_specs`` supplies 256 pre-computed 1024-d patch embeddings
per image, projected into the LM by a learned linear (the projector's
second half).  Full attention → ``long_500k`` skipped.
"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    layer_pattern=(ATTN,),
    gated_mlp=True,
    mlp_act="silu",
    frontend="vision_patches",
    frontend_dim=1024,
    num_prefix_tokens=256,
    tie_embeddings=True,
    remat="none",
    source="arXiv:2404.16821",
))
