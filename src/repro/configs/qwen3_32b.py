"""Qwen3-32B [hf:Qwen/Qwen3-8B family card] — dense decoder, qk-norm GQA.

64L, d_model=5120, 64 heads (GQA kv=8), head_dim=128 (q-proj dim 8192 >
d_model), d_ff=25600, vocab=151936, SwiGLU, qk-norm, no QKV bias.
Full attention → ``long_500k`` skipped.
"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    layer_pattern=(ATTN,),
    gated_mlp=True,
    mlp_act="silu",
    remat="full",
    source="hf:Qwen/Qwen3-8B",
))
