"""Input specs (ShapeDtypeStruct stand-ins) for every (arch × shape) pair,
plus reduced smoke variants for CPU tests.

Decode shapes lower ``serve_step`` — ONE new token against a KV cache /
recurrent state of ``seq_len`` — not ``train_step``.  ``input_specs``
allocates nothing: caches come from ``jax.eval_shape``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import (INPUT_SHAPES, InputShape, ModelConfig,
                                MoEConfig)

SDS = jax.ShapeDtypeStruct


def shape_supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch × shape) is runnable; else the documented skip reason."""
    if shape.kind in ("decode",) and cfg.is_encoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = cfg.arch_type in ("ssm", "hybrid") or (
            cfg.sliding_window > 0 and all(
                k == "attn_local" for k in cfg.layer_pattern))
        if not sub_quadratic:
            if cfg.name == "gemma2-2b":
                # runs via the registered sliding-window-only variant
                return True, "uses gemma2-2b-swa sliding-window decode variant"
            return False, "full-attention arch at 500k context (documented skip)"
    return True, ""


def resolve_decode_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Arch variant actually lowered for this shape (gemma2 long-context
    decode swaps to the sliding-window-only variant)."""
    if shape.name == "long_500k" and cfg.name == "gemma2-2b":
        from repro.configs.base import get_config
        return get_config("gemma2-2b-swa")
    return cfg


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct pytree for the step function of ``shape.kind``."""
    B, S = shape.global_batch, shape.seq_len
    adt = cfg.act_dtype
    cfg = resolve_decode_config(cfg, shape)
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "token":
            batch = {"tokens": SDS((B, S), jnp.int32)}
        elif cfg.frontend == "vision_patches":
            P = cfg.num_prefix_tokens
            batch = {"patches": SDS((B, P, cfg.frontend_dim), adt),
                     "tokens": SDS((B, S - P), jnp.int32)}
        elif cfg.frontend == "audio_frames":
            batch = {"frames": SDS((B, S, cfg.frontend_dim), adt),
                     "mask": SDS((B, S), jnp.bool_),
                     "labels": SDS((B, S), jnp.int32)}
        else:
            raise ValueError(cfg.frontend)
        return {"batch": batch}
    if shape.kind == "decode":
        from repro.models.transformer import init_caches
        caches = jax.eval_shape(lambda: init_caches(cfg, B, S, adt))
        return {"tokens": SDS((B, 1), jnp.int32),
                "caches": caches,
                "pos": SDS((), jnp.int32)}
    raise ValueError(shape.kind)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family: ≤2 groups, d_model ≤ 512,
    ≤4 experts — runs a real forward/train step on CPU."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    while heads % kv:
        kv += 1
    head_dim = max(d // heads, 32)
    kw: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=cfg.period * min(cfg.num_groups, 2),
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 4 * d) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 503 if cfg.is_encoder else 512),
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        frontend_dim=min(cfg.frontend_dim, 64) if cfg.frontend_dim else 0,
        num_prefix_tokens=min(cfg.num_prefix_tokens, 4) if cfg.num_prefix_tokens else 0,
        dtype="float32",
        remat="none",
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 2 * d))
    return cfg.replace(**kw)


def smoke_shape(kind: str = "train", seq: int = 32, batch: int = 2) -> InputShape:
    return InputShape(f"smoke_{kind}", seq, batch, kind)
