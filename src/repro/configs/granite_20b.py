"""Granite-20B-Code [arXiv:2405.04324] — dense decoder, GPT-BigCode arch.

52L, d_model=6144, 48 heads, MQA (kv=1), d_ff=24576, vocab=49152.
Plain GELU MLP (non-gated), biases on QKV, tied embeddings.
Adaptation: learned absolute positions (8k table) replaced by RoPE so the
32k-prefill shape is addressable (DESIGN.md §8).  MQA: the single KV head
is replicated across the model axis (cannot shard 1 head 16-way).
Pure full attention → ``long_500k`` is a documented skip.
"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-20b",
    arch_type="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,
    layer_pattern=(ATTN,),
    gated_mlp=False,
    mlp_act="gelu",
    tie_embeddings=True,
    remat="full",
    source="arXiv:2405.04324",
))
