"""Qwen2-7B [arXiv:2407.10671] — dense decoder, GQA with QKV bias.

28L, d_model=3584, 28 heads (GQA kv=4), d_ff=18944, vocab=152064, SwiGLU.
Full attention → ``long_500k`` skipped.
"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    layer_pattern=(ATTN,),
    gated_mlp=True,
    mlp_act="silu",
    remat="full",
    source="arXiv:2407.10671",
))
