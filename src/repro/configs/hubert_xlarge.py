"""HuBERT X-Large [arXiv:2106.07447] — audio encoder (wav2vec2 arch).

48L, d_model=1280, 16 heads (MHA), d_ff=5120, vocab=504 (k-means target
codebook).  Encoder-only: bidirectional attention, masked-prediction loss,
no decode step (decode shapes are documented skips, DESIGN.md §5).

Frontend carve-out: the mel/conv feature extractor is a stub —
``input_specs`` supplies pre-computed 512-d frame embeddings.
Adaptation note: HuBERT's conv positional embedding is replaced by RoPE
(TPU-native, length-generalising); recorded in DESIGN.md §8.
"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    layer_pattern=(ATTN,),
    is_encoder=True,
    gated_mlp=False,
    mlp_act="gelu",
    frontend="audio_frames",
    frontend_dim=512,
    remat="full",
    source="arXiv:2106.07447",
))
