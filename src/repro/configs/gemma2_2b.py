"""Gemma-2 2B [arXiv:2408.00118] — dense decoder with alternating
local(4096-window)/global attention, logit softcaps, GeGLU, post-norms.

26L, d_model=2304, 8 heads (GQA kv=4), head_dim=256, d_ff=9216,
vocab=256000, attn softcap 50.0, final softcap 30.0, tied embeddings,
embeddings scaled by sqrt(d).

``long_500k``: runs with the sliding-window decode variant (global layers
windowed at decode) — a beyond-paper variant recorded in DESIGN.md §5.
"""
from repro.configs.base import ATTN, ATTN_LOCAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    layer_pattern=(ATTN_LOCAL, ATTN),
    gated_mlp=True,
    mlp_act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    post_norms=True,
    remat="full",
    source="arXiv:2408.00118",
))

# Sliding-window-only decode variant used for the long_500k shape: every
# layer is windowed, making decode memory O(window), not O(context).
CONFIG_SWA = register(CONFIG.replace(
    name="gemma2-2b-swa",
    layer_pattern=(ATTN_LOCAL, ATTN_LOCAL),
))
