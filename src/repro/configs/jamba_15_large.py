"""Jamba-1.5-Large (398B total) [arXiv:2403.19887] — hybrid Mamba+attention
with MoE.

72L, d_model=8192, 64 heads (GQA kv=8), vocab=65536.  Period-8 Jamba block:
attention at in-block index 4, Mamba elsewhere (1:7 ratio); MoE every 2nd
layer (16 experts, top-2, expert d_ff=24576), dense d_ff=24576 otherwise.
Mamba: d_state=16, d_conv=4, expand=2.

Mamba layers decode with O(1) state and the single attention layer per
block has a shardable KV cache → ``long_500k`` runs natively.
"""
from repro.configs.base import (ATTN, MAMBA, MambaConfig, ModelConfig,
                                MoEConfig, register)

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    layer_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, every_n=2,
                  moe_offset=1),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    gated_mlp=True,
    mlp_act="silu",
    remat="full",
    source="arXiv:2403.19887",
))
