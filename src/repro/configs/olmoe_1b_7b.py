"""OLMoE-1B-7B [arXiv:2409.02060] — MoE decoder, 64 experts top-8.

16L, d_model=2048, 16 heads (MHA kv=16), vocab=50304, qk-norm; every FFN
is MoE: 64 experts, top-8, expert d_ff=1024, SwiGLU experts.
Full attention → ``long_500k`` skipped.
"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=50304,
    qk_norm=True,
    layer_pattern=(ATTN,),
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    gated_mlp=True,
    mlp_act="silu",
    remat="full",
    source="arXiv:2409.02060",
))
