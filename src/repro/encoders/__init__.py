from repro.encoders.foundation import FrozenFM, category_encodings
