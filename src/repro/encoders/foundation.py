"""Frozen foundation-model encoder — the BLIP→CLIP stand-in (DESIGN.md §8).

The paper's clients run ``y_cn = CLIP_text(BLIP(x_cn))`` (Eq. 6) with
FROZEN weights, zero-shot.  What OSCAR needs from this pipeline is a frozen
deterministic map image → R^512 whose geometry reflects semantic content
(same category ⇒ nearby encodings).  We realise that with a fixed-seed
random convolutional feature extractor + projection (random features
preserve input geometry); the diffusion model is then *trained with these
encodings as conditioning*, exactly as SD was trained with CLIP encodings.

Nothing here is ever trained or communicated except the final 512-d
vectors — matching the paper's communication accounting (512 floats per
category per client).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class FrozenFM:
    """Deterministic frozen encoder: images (B,H,W,C) in [-1,1] -> (B,512).

    A small hand-fixed multi-scale vision backbone (pooled colour stats,
    edge-energy maps, soft colour histograms, a low-res view, and random
    nonlinear patch features) followed by a fixed random projection —
    frozen, zero-shot, and strongly category-informative, as a real
    foundation encoder would be."""

    def __init__(self, dim: int = 512, seed: int = 1234, patch: int = 4):
        self.dim = dim
        self.patch = patch
        self._rng = np.random.default_rng(seed)
        self._built = None

    def _build(self, H, W, C, feat_dim):
        p = self.patch
        pd = p * p * C
        w1 = self._rng.normal(size=(pd, 128)) / np.sqrt(pd)
        wo = self._rng.normal(size=(feat_dim, self.dim)) / np.sqrt(feat_dim)
        self._proj = (jnp.asarray(w1, jnp.float32), jnp.asarray(wo, jnp.float32))
        self._built = (H, W, C, feat_dim)

    def _features(self, images):
        B, H, W, C = images.shape
        p = self.patch

        def pool(x, g):
            return x.reshape(B, g, H // g, g, W // g, C).mean((2, 4)).reshape(B, -1)

        f_pool4 = pool(images, 4)                            # 4×4 grid stats
        f_pool2 = pool(images, 2)
        dx = jnp.diff(images, axis=2, append=images[:, :, -1:])
        dy = jnp.diff(images, axis=1, append=images[:, -1:])
        edge = jnp.sqrt(dx ** 2 + dy ** 2 + 1e-8).mean(-1, keepdims=True)
        f_edge = edge.reshape(B, 4, H // 4, 4, W // 4, 1).mean((2, 4)).reshape(B, -1)
        bins = jnp.linspace(-1, 1, 5)
        f_hist = jax.nn.softmax(-((images[..., None] - bins) ** 2) / 0.125,
                                axis=-1).mean((1, 2)).reshape(B, -1)
        small = images.reshape(B, 8, H // 8, 8, W // 8, C).mean((2, 4)).reshape(B, -1)
        x = images.reshape(B, H // p, p, W // p, p, C).transpose(0, 1, 3, 2, 4, 5)
        x = x.reshape(B, -1, p * p * C)
        return [f_pool4, f_pool2, f_edge, f_hist, small], x

    def __call__(self, images) -> jax.Array:
        images = jnp.asarray(images, jnp.float32)
        B, H, W, C = images.shape
        # first pass builds projections once the feature dim is known
        feats, xpatch = self._features(images)
        pd = self.patch * self.patch * C
        if self._built is None or self._built[:3] != (H, W, C):
            base = sum(f.shape[-1] for f in feats)
            self._build(H, W, C, base + 128)
        w1, wo = self._proj
        f_rand = jnp.tanh(xpatch @ w1).mean(1)
        z = jnp.concatenate(feats + [f_rand], axis=-1) @ wo   # (B, 512)
        z = z - jnp.mean(z, axis=-1, keepdims=True)
        return z / (jnp.linalg.norm(z, axis=-1, keepdims=True) + 1e-6)


def category_encodings(fm: FrozenFM, images, labels, num_categories: int):
    """Eq. 6 + Eq. 7: encode every image, mean-pool per category.

    Returns (ȳ (C, 512), present (C,) bool) — ȳ_c is zero for absent
    categories.  ȳ is exactly what a client uploads (C × 512 floats)."""
    z = fm(images)
    C = num_categories
    out = jnp.zeros((C, z.shape[-1]), jnp.float32)
    cnt = jnp.zeros((C,), jnp.float32)
    out = out.at[labels].add(z)
    cnt = cnt.at[labels].add(1.0)
    present = cnt > 0
    mean = out / jnp.maximum(cnt[:, None], 1.0)
    # re-project the mean onto the unit sphere: the DM is conditioned on
    # unit-norm encodings (CLIP convention), and a mean of unit vectors is
    # shorter — without this the server conditions out-of-distribution.
    mean = mean / (jnp.linalg.norm(mean, axis=-1, keepdims=True) + 1e-6)
    mean = jnp.where(present[:, None], mean, 0.0)
    return mean, present
