"""Production training launcher: builds the mesh, shards the train state
per the partition rules, and runs the jitted train step.

On real TPU slices this is the entry point (the dry-run lowers exactly
this step function); on CPU it runs reduced configs on a host mesh:

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.mesh import make_host_mesh, make_production_mesh, mesh_axes
from repro.models.moe import Parallel
from repro.optim import init_adamw
from repro.sharding.rules import batch_specs, param_specs, to_shardings
from repro.train.steps import TrainState, init_train_state, make_train_step
from repro.configs.base import InputShape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-shards", type=int, default=1)
    ap.add_argument("--model-shards", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(args.data_shards, args.model_shards) \
        if args.data_shards * args.model_shards <= n_dev \
        else make_production_mesh()
    ax = mesh_axes(mesh)
    par = Parallel(model_axis=ax.model, data_axes=ax.data, mesh=mesh)

    key = jax.random.PRNGKey(0)
    state = init_train_state(key, cfg)
    pspecs = param_specs(state.params, ax)
    from repro.optim.optimizers import AdamWState
    state_specs = TrainState(pspecs, AdamWState(
        jax.sharding.PartitionSpec(), pspecs, pspecs))
    state = jax.device_put(state, to_shardings(state_specs, mesh))

    shape = InputShape("cli", args.seq, args.batch, "train")
    bspecs = batch_specs(cfg, shape, ax, batch_sharded=True)
    step = jax.jit(make_train_step(cfg, par, lr=args.lr),
                   in_shardings=(to_shardings(state_specs, mesh),
                                 to_shardings(bspecs, mesh)),
                   donate_argnums=(0,))

    print(f"[launch] {cfg.name} on mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    t0 = time.time()
    for i in range(args.steps):
        kb = jax.random.fold_in(key, i)
        if cfg.frontend == "token":
            batch = {"tokens": jax.random.randint(kb, (args.batch, args.seq),
                                                  0, cfg.vocab_size)}
        elif cfg.frontend == "audio_frames":
            batch = {"frames": jax.random.normal(kb, (args.batch, args.seq,
                                                      cfg.frontend_dim)),
                     "mask": jax.random.bernoulli(kb, 0.3, (args.batch, args.seq)),
                     "labels": jax.random.randint(kb, (args.batch, args.seq),
                                                  0, cfg.vocab_size)}
        else:
            P = cfg.num_prefix_tokens
            batch = {"patches": jax.random.normal(kb, (args.batch, P,
                                                       cfg.frontend_dim)),
                     "tokens": jax.random.randint(kb, (args.batch,
                                                       args.seq - P),
                                                  0, cfg.vocab_size)}
        batch = jax.device_put(batch, to_shardings(bspecs, mesh))
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"  step {i:4d} loss {float(metrics['loss']):.4f}",
                  flush=True)
    print(f"[launch] {args.steps} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
