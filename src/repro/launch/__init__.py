"""Launchers: mesh construction, multi-pod dry-run, training/serving CLIs.

``repro.launch.dryrun`` must only run as a __main__ subprocess (it forces
a 512-device host platform before jax init).
"""
