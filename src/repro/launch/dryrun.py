import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape) on the
production mesh, with zero allocation (ShapeDtypeStruct stand-ins).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per pair this records: memory analysis (bytes/device), cost analysis
(FLOPs, HBM bytes), the collective schedule (bytes-on-wire by kind), and
the three roofline terms.  Results merge into a JSON cache consumed by
``benchmarks/roofline.py`` and EXPERIMENTS.md.

NOTE: the XLA_FLAGS line above must run before ANY jax import — jax locks
the device count on first init.  Do not import this module from test or
benchmark code (they must see 1 device); shell out instead.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, input_specs
from repro.configs.shapes import resolve_decode_config, shape_supported
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.models.moe import Parallel
from repro.models.transformer import decode_step, init_lm
from repro.optim import init_adamw
from repro.serve.steps import make_prefill_step
from repro.sharding.rules import (batch_specs, cache_specs, param_specs,
                                  to_shardings)
from repro.train.steps import TrainState, make_train_step
from repro.utils import tree_bytes, tree_size

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def _sharded_bytes(sds_tree, spec_tree, mesh) -> float:
    """Per-device bytes of a pytree under the given specs (analytic)."""
    import math
    total = 0.0
    leaves_s = jax.tree.leaves(sds_tree)
    leaves_p = jax.tree.leaves(spec_tree,
                               is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    for sds, spec in zip(leaves_s, leaves_p):
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for n in names:
                shards *= mesh.shape[n]
        total += math.prod(sds.shape) * sds.dtype.itemsize / shards
    return total


def build(arch: str, shape_name: str, *, multi_pod: bool, overrides: dict | None = None):
    """Lower + compile one (arch × shape × mesh).  Returns result dict."""
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    ok, note = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skip", "note": note}
    cfg = resolve_decode_config(cfg, shape)
    overrides = overrides or {}
    par_kw = {k: v for k, v in overrides.items()
              if k in ("moe_combine", "use_pallas", "attn_impl",
                       "prefill_last_only", "gqa_repeat", "decode_cache")}
    cfg_kw = {k: v for k, v in overrides.items() if k in ("remat", "dtype")}
    if cfg_kw:
        cfg = cfg.replace(**cfg_kw)

    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = mesh_axes(mesh)
    n_dev = mesh.size
    data_shards = int(np.prod([mesh.shape[a] for a in ax.data]))
    batch_sharded = shape.global_batch % data_shards == 0

    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    bdim = ax.all_data if batch_sharded else None
    par = Parallel(model_axis="model", data_axes=ax.data, mesh=mesh,
                   batch_sharded=batch_sharded,
                   logits_spec=NamedSharding(mesh, P(bdim, None, "model")),
                   **par_kw)
    if overrides.get("seq_parallel"):
        par = Parallel(**{**par.__dict__,
                          "resid_spec": NamedSharding(mesh, P(bdim, "model", None))})
    if overrides.get("shard_heads"):
        # pin q on (padded) head sharding over model; kv replicated on model
        par = Parallel(**{**par.__dict__,
                          "qkv_spec": (NamedSharding(mesh, P(bdim, None, "model", None)),
                                       NamedSharding(mesh, P(bdim, None, None, None)))})

    specs = input_specs(cfg, shape)
    params_sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    if shape.kind != "train":
        # serving runs on cast weights (bf16), not f32 optimizer masters
        params_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, cfg.act_dtype)
            if jnp.issubdtype(s.dtype, jnp.floating) else s, params_sds)
    p_mode = "train"
    if shape.kind != "train":
        if overrides.get("serve2d"):
            p_mode = "serve2d"
        elif overrides.get("serve1d"):
            p_mode = "serve1d"
    pspecs = param_specs(params_sds, ax, mode=p_mode)
    psh = to_shardings(pspecs, mesh)

    t0 = time.time()
    if shape.kind == "train":
        state_sds = TrainState(params_sds,
                               jax.eval_shape(lambda: init_adamw(params_sds)))
        opt_specs = jax.eval_shape(lambda: init_adamw(params_sds))  # structure
        from repro.optim.optimizers import AdamWState
        state_specs = TrainState(
            pspecs, AdamWState(jax.sharding.PartitionSpec(), pspecs, pspecs))
        state_sh = to_shardings(state_specs, mesh)
        b_specs = batch_specs(cfg, shape, ax, batch_sharded)
        b_sh = to_shardings(b_specs, mesh)
        step = make_train_step(cfg, par)
        jitted = jax.jit(step, in_shardings=(state_sh, b_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        lowered = jitted.lower(state_sds, specs["batch"])
        arg_bytes = _sharded_bytes(state_sds, state_specs, mesh)
    elif shape.kind == "prefill":
        b_specs = batch_specs(cfg, shape, ax, batch_sharded)
        b_sh = to_shardings(b_specs, mesh)
        step = make_prefill_step(cfg, par)
        jitted = jax.jit(step, in_shardings=(psh, b_sh))
        lowered = jitted.lower(params_sds, specs["batch"])
        arg_bytes = _sharded_bytes(params_sds, pspecs, mesh)
    else:  # decode
        c_specs = cache_specs(cfg, shape, ax, batch_sharded, specs["caches"])
        c_sh = to_shardings(c_specs, mesh)
        bdim = ax.all_data if batch_sharded else None
        tok_sh = to_shardings(jax.sharding.PartitionSpec(bdim, None), mesh)
        pos_sh = to_shardings(jax.sharding.PartitionSpec(), mesh)
        fn = lambda p, t, c, pos: decode_step(p, cfg, t, c, pos, par)
        jitted = jax.jit(fn, in_shardings=(psh, tok_sh, c_sh, pos_sh),
                         out_shardings=(None, c_sh), donate_argnums=(2,))
        lowered = jitted.lower(params_sds, specs["tokens"], specs["caches"],
                               specs["pos"])
        arg_bytes = (_sharded_bytes(params_sds, pspecs, mesh)
                     + _sharded_bytes(specs["caches"], c_specs, mesh))
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # ---- memory ----
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not support it
        mem["error"] = str(e)
    mem["arg_bytes_analytic_per_device"] = arg_bytes

    # ---- trip-count-aware FLOPs / bytes / collectives (see hlo_analysis) ----
    hlo_text = compiled.as_text()
    cost = hlo.analyze(hlo_text, n_dev)
    flops, bytes_accessed = cost.flops, cost.bytes

    terms = hlo.roofline_terms(flops, bytes_accessed, cost.collective_bytes)
    pc = cfg.param_counts()
    # MODEL_FLOPS: 6·N·D for training, 2·N·D forward-only (decode/prefill)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf_factor = 6 if shape.kind == "train" else 2
    model_flops = mf_factor * pc["active"] * tokens
    flops_global = flops * n_dev

    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "note": note,
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a]) for a in mesh.axis_names])),
        "n_devices": n_dev,
        "batch_sharded": batch_sharded,
        "overrides": overrides,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "params_total": pc["total"], "params_active": pc["active"],
        "flops_per_device": flops, "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": cost.collective_bytes,
        "collectives": {k: {"bytes": v, "count": cost.coll_count_by_kind[k]}
                        for k, v in cost.coll_bytes_by_kind.items()},
        "roofline": terms,
        "bottleneck": hlo.dominant_term(terms),
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / flops_global) if flops_global else None,
        "memory": mem,
    }
    return result


def merge_result(result: dict, out_path: Path):
    out_path.parent.mkdir(parents=True, exist_ok=True)
    data = {}
    if out_path.exists():
        data = json.loads(out_path.read_text())
    key = "|".join([result["arch"], result["shape"],
                    "2pod" if result["multi_pod"] else "1pod",
                    json.dumps(result.get("overrides") or {}, sort_keys=True)])
    data[key] = result
    out_path.write_text(json.dumps(data, indent=1, sort_keys=True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR / "dryrun.json"))
    ap.add_argument("--override", action="append", default=[],
                    help="k=v (remat, dtype, moe_combine, seq_parallel)")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(v.lower(), v)

    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs.append((args.arch, args.shape))

    out_path = Path(args.out)
    for arch, shape in pairs:
        print(f"=== dry-run {arch} × {shape} "
              f"({'2-pod 512' if args.multi_pod else '1-pod 256'} chips) ===",
              flush=True)
        try:
            res = build(arch, shape, multi_pod=args.multi_pod,
                        overrides=overrides)
        except Exception:
            res = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                   "status": "error", "error": traceback.format_exc(),
                   "overrides": overrides}
        merge_result(res, out_path)
        if res["status"] == "ok":
            t = res["roofline"]
            print(f"  lower {res['t_lower_s']}s compile {res['t_compile_s']}s | "
                  f"flops/dev {res['flops_per_device']:.3e} "
                  f"bytes/dev {res['bytes_per_device']:.3e} "
                  f"coll/dev {res['collective_bytes_per_device']:.3e}")
            print(f"  roofline: compute {t['t_compute']*1e3:.2f}ms "
                  f"memory {t['t_memory']*1e3:.2f}ms "
                  f"collective {t['t_collective']*1e3:.2f}ms "
                  f"-> {res['bottleneck']}-bound | useful-flops "
                  f"{(res['useful_flops_ratio'] or 0):.2f}")
            print(f"  memory: {res['memory']}")
        else:
            print(f"  {res['status'].upper()}: "
                  f"{res.get('note') or res.get('error', '')[-2000:]}")


if __name__ == "__main__":
    main()
