"""Production mesh construction (TPU v5e target).

FUNCTIONS, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax initialisation).

Two mesh families:

* training/decode meshes — ``(data, model)`` (+ a leading ``pod`` axis
  multi-pod): the layouts ``sharding/rules.py`` partitions parameters
  over;
* the SERVING mesh — ``("hosts", "data", "model")``: an explicit host
  PLACEMENT axis ahead of the per-host compute axes.  ``hosts`` is not a
  sharding axis — ``mesh_axes`` excludes it from the data axes — it
  partitions the device set into the per-host submeshes
  (``host_submesh``) that ``serve/topology.py::HostTopology.from_mesh``
  places synthesis waves over.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.sharding.rules import MeshAxes


def _validate_device_count(shape: tuple, axes: tuple):
    """Fail fast with an actionable error instead of deep inside
    ``jax.make_mesh`` when the runtime has fewer devices than the mesh
    needs (``make_mesh`` itself tolerates a surplus — it takes a
    prefix)."""
    need = int(np.prod(shape))
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {need} devices but only "
            f"{have} {jax.default_backend()} device(s) are visible — run "
            f"on the pod this mesh targets, or build a local mesh with "
            f"make_host_mesh(data, model) / make_serving_mesh(hosts=..., "
            f"data=..., model=...) sized to jax.device_count()")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    _validate_device_count(shape, axes)
    return jax.make_mesh(shape, axes)


def make_serving_mesh(*, hosts: int = 1, data: int = 1, model: int = 1):
    """Serving mesh: ``hosts`` placement groups, each a (data, model)
    compute submesh.  ``hosts * data * model`` must not exceed the
    visible device count."""
    if min(hosts, data, model) < 1:
        raise ValueError(f"make_serving_mesh: hosts={hosts} data={data} "
                         f"model={model} must all be >= 1")
    shape, axes = (hosts, data, model), ("hosts", "data", "model")
    _validate_device_count(shape, axes)
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> MeshAxes:
    """The (data, model) view of any mesh.  ``model`` is tensor-parallel;
    everything else is batch-parallel EXCEPT the serving mesh's ``hosts``
    axis, which is placement (one submesh per host), never sharding."""
    names = mesh.axis_names
    data = tuple(n for n in names if n not in ("model", "hosts"))
    return MeshAxes(data=data, model="model")


def host_submesh(mesh, host: int):
    """Host ``host``'s compute mesh: the ``hosts`` axis sliced away,
    leaving that host's own (data, model) device block."""
    from jax.sharding import Mesh
    if "hosts" not in mesh.axis_names:
        raise ValueError(
            f"mesh axes {mesh.axis_names} carry no 'hosts' axis — build "
            f"one with make_serving_mesh(hosts=...)")
    n_hosts = int(mesh.shape["hosts"])
    if not 0 <= host < n_hosts:
        raise ValueError(f"host {host} out of range for a {n_hosts}-host "
                         f"serving mesh")
    axis = mesh.axis_names.index("hosts")
    devices = np.take(mesh.devices, host, axis=axis)
    return Mesh(devices, tuple(n for n in mesh.axis_names if n != "hosts"))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/benches."""
    _validate_device_count((data, model), ("data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
