"""Production mesh construction (TPU v5e target).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import jax

from repro.sharding.rules import MeshAxes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> MeshAxes:
    names = mesh.axis_names
    data = tuple(n for n in names if n != "model")
    return MeshAxes(data=data, model="model")


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/benches."""
    return jax.make_mesh((data, model), ("data", "model"))
