"""HLO post-processing: trip-count-aware FLOP/byte/collective accounting
plus roofline terms.

Why not ``compiled.cost_analysis()``: XLA's analysis counts each ``while``
body ONCE, but our stacks scan over layer groups (a 64-layer qwen3 runs its
body 64×) — verified experimentally, so we parse the optimised HLO text and
scale every computation by the loop trip count XLA records in
``backend_config={"known_trip_count":{"n":...}}``.

Accounting model (per-device; the SPMD module is already partitioned):
* FLOPs — ``dot``: 2·|result|·(contracted dims);  reductions: |operand|;
  other float elementwise ops: |result|;  data-movement ops: 0.
* HBM bytes — for every top-level instruction of a non-fused computation:
  |result| + Σ|operands| (fusion internals are VMEM-resident and skipped).
* Collective bytes-on-wire — ring factors:
    all-reduce 2(n-1)/n·|res|, all-gather (n-1)/n·|res|,
    reduce-scatter (n-1)·|res|, all-to-all (n-1)/n·|res|,
    collective-permute |res|,  n = participants per replica group.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

# TPU v5e hardware constants (per task spec).
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link
ICI_LINKS = 4             # v5e: 4 ICI links per chip (2D torus x±, y±)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}
_FLOAT_DTYPES = {"bf16", "f16", "f32", "f64", "f8e4m3fn", "f8e5m2"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HDR_ARG_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],]+))")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],]+(?:\{[\d,]*\})?))\s+([\w\-]+)(?:\(|\.)")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->", )
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count"?\s*[:=]\s*\{"?n"?\s*[:=]\s*"?(\d+)"?\}')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_ZERO_FLOP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "broadcast", "reshape", "transpose", "copy", "copy-start", "copy-done",
    "slice", "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "iota", "convert", "gather", "scatter", "reverse", "while", "call",
    "conditional", "custom-call", "after-all", "all-gather", "all-reduce",
    "reduce-scatter", "all-to-all", "collective-permute", "partition-id",
    "replica-id", "rng-bit-generator", "optimization-barrier", "domain",
    "send", "recv", "send-done", "recv-done", "infeed", "outfeed", "fusion",
    "get-dimension-size", "add-dependency",
}
_DATA_MOVEMENT = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "optimization-barrier", "domain", "partition-id",
    "replica-id", "get-dimension-size", "add-dependency",
    # bodies are counted separately; the call-op carry tuples are not traffic
    "while", "call", "conditional",
}
# ops that touch only a slice of their big operand: bytes = 2·|slice|
_SLICE_READ_OPS = {"dynamic-slice", "slice", "gather"}
# in-place update ops: bytes = 2·|update operand| (read-modify-write)
_UPDATE_OPS = {"dynamic-update-slice": 1, "scatter": 2}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_numel_bytes(shape_str: str) -> tuple[int, int]:
    """(numel, bytes) summed over a possibly-tuple shape string."""
    numel = total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel += n
        total += n * _DTYPE_BYTES[dt]
    return numel, total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return default


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_bytes_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    coll_count_by_kind: dict = field(default_factory=lambda: defaultdict(int))


class _Instr:
    __slots__ = ("name", "shape", "op", "line")

    def __init__(self, name, shape, op, line):
        self.name, self.shape, self.op, self.line = name, shape, op, line


def _parse(text: str):
    """Returns (comps: name -> [instrs], symbols: name -> shape str,
    entry name, comp_params: name -> [param names in order])."""
    comps: dict[str, list[_Instr]] = {}
    symbols: dict[str, str] = {}
    comp_params: dict[str, list[str]] = {}
    current = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.startswith("HloModule"):
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "{" in line:
            current = hdr.group(2)
            comps[current] = []
            if hdr.group(1):
                entry = current
            # computation parameters: "(name: f32[a,b], ...)" -> symbols
            arglist = line[line.find("("):line.rfind("->")]
            names = []
            for am in _HDR_ARG_RE.finditer(arglist):
                symbols[am.group(1)] = am.group(2)
                names.append(am.group(1))
            comp_params[current] = names
            continue
        if current is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape, op = m.group(1), m.group(2), m.group(3)
        symbols[name] = shape
        comps[current].append(_Instr(name, shape, op, line))
    return comps, symbols, entry, comp_params


def _multipliers(comps, entry):
    """Computation execution multipliers from while trip counts."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # edges: (caller, callee, factor, kind)
    order = [entry]
    seen = {entry}
    while order:
        nxt = []
        for cname in order:
            cm = mult[cname]
            for ins in comps.get(cname, []):
                factors = []
                if ins.op == "while":
                    t = _TRIP_RE.search(ins.line)
                    n = float(t.group(1)) if t else 1.0
                    b = _BODY_RE.search(ins.line)
                    c = _COND_RE.search(ins.line)
                    if b:
                        factors.append((b.group(1), n))
                    if c:
                        factors.append((c.group(1), n))
                elif ins.op in ("fusion", "call", "map"):
                    m = _CALLS_RE.search(ins.line) or re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                    if m:
                        factors.append((m.group(1), 1.0))
                elif ins.op == "conditional":
                    for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|(?:true|false)_computation=%?([\w.\-]+))", ins.line):
                        names = (m.group(1) or m.group(2) or "").replace("%", "")
                        for nm in names.split(","):
                            nm = nm.strip()
                            if nm:
                                factors.append((nm, 1.0))
                # NOTE: reduce/sort to_apply bodies intentionally not visited
                for callee, f in factors:
                    newm = cm * f
                    if newm > mult[callee] + 1e-9:
                        mult[callee] = newm
                        if callee not in seen or True:
                            nxt.append(callee)
                            seen.add(callee)
        order = nxt
    return mult


# computations reached via fusion `calls=` contribute flops but no HBM bytes
def _fused_comps(comps):
    fused = set()
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "fusion":
                m = _CALLS_RE.search(ins.line)
                if m:
                    fused.add(m.group(1))
    return fused


def _dot_flops(ins: _Instr, symbols) -> float:
    res_numel, _ = _shape_numel_bytes(ins.shape)
    ops = _OPERAND_RE.findall(ins.line.split("(", 1)[1])
    lhs_shape = symbols.get(ops[0], "") if ops else ""
    cdims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    cdims = [int(d) for d in cdims_m.group(1).split(",") if d] if cdims_m else []
    k = 1
    m = _SHAPE_RE.search(lhs_shape)
    if m and cdims:
        lhs_dims = [int(d) for d in m.group(2).split(",") if d]
        for d in cdims:
            if d < len(lhs_dims):
                k *= lhs_dims[d]
    return 2.0 * res_numel * k


def _fusion_effective_bytes(ins, comps, symbols, comp_params, res_b, opnds):
    """Traffic estimate for one fusion call.

    * a parameter used ONLY via slice-reads (dynamic-slice/slice/gather)
      contributes the sliced bytes, not the full buffer;
    * a DUS-rooted fusion aliases its big operand in place: traffic is the
      updated slice, not the whole buffer.
    """
    cal = _CALLS_RE.search(ins.line)
    callee = cal.group(1) if cal else None
    internal = comps.get(callee, [])
    # map fusion operands -> parameter names by the parameter(N) index
    # (header order is NOT numeric order in optimised HLO)
    plist_map: dict[int, str] = {}
    for i in internal:
        if i.op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", i.line)
            if pm:
                plist_map[int(pm.group(1))] = i.name
    if not plist_map:
        names = comp_params.get(callee, [])
        plist_map = dict(enumerate(names))
    plist = [plist_map.get(i) for i in range(len(opnds))]
    pnames = set(n for n in plist if n)
    # CPU bf16-dot emulation artifact: a fusion that only converts dtypes /
    # re-lays-out dot operands materialises an f32 (or transposed) shadow
    # of a bf16 buffer; the TPU MXU consumes bf16 in native tiled layouts —
    # count as zero traffic (documented in EXPERIMENTS.md §Roofline notes).
    body_ops = {i.op for i in internal if i.op != "parameter"}
    if body_ops and body_ops <= {"convert", "bitcast", "copy", "reshape",
                                 "transpose"}:
        return 0.0

    def dims_of(shape_str):
        m = _SHAPE_RE.search(shape_str)
        return m.group(2) if m else ""

    res_dims = dims_of(ins.shape)

    # transparent single-operand ops: resolve back to the source param
    alias: dict[str, str] = {}

    def resolve(name):
        seen = 0
        while name in alias and seen < 20:
            name = alias[name]
            seen += 1
        return name

    # per-parameter usage scan
    slice_only: dict[str, float] = {}     # param -> sliced bytes
    full_use: set[str] = set()
    dus_updates = 0.0
    dus_targets: set[str] = set()
    for i in internal:
        args = _OPERAND_RE.findall(i.line.split("(", 1)[1]) if "(" in i.line else []
        args = [resolve(a) for a in args]
        if i.op in ("convert", "bitcast", "copy", "reshape") and len(args) == 1:
            alias[i.name] = args[0]
            continue
        if i.op in ("dynamic-slice", "slice", "gather"):
            _, rb = _shape_numel_bytes(i.shape)
            if args and args[0] in pnames:
                slice_only[args[0]] = slice_only.get(args[0], 0.0) + rb
            continue
        if i.op == "dynamic-update-slice":
            if len(args) > 1 and args[1] in symbols:
                dus_updates += _shape_numel_bytes(symbols[args[1]])[1]
            if args and args[0] in pnames:
                dus_targets.add(args[0])
            continue
        for a in args:
            if a in pnames:
                full_use.add(a)

    total = 0.0
    aliased_out = 0.0
    for k, opn in enumerate(opnds):
        if opn not in symbols:
            continue
        pname = plist[k] if k < len(plist) else None
        _, b = _shape_numel_bytes(symbols[opn])
        if pname in dus_targets and pname not in full_use:
            # in-place updated buffer: reads/writes only the slice (a dtype
            # change would be real traffic — require exact byte match)
            if dims_of(symbols[opn]) == res_dims and b == res_b:
                aliased_out = max(aliased_out, b)
            continue
        if pname is not None and pname in slice_only and pname not in full_use:
            total += slice_only[pname]
        else:
            total += b
    total += max(0.0, res_b - aliased_out) + 2 * dus_updates
    return total


def analyze(text: str, total_devices: int) -> HloCost:
    comps, symbols, entry, comp_params = _parse(text)
    mult = _multipliers(comps, entry)
    fused = _fused_comps(comps)
    cost = HloCost()
    for cname, instrs in comps.items():
        cm = mult.get(cname, 0.0)
        if cm == 0.0:
            continue
        in_fusion = cname in fused
        for ins in instrs:
            base_op = ins.op.replace("-start", "").replace("-done", "")
            # ---- flops ----
            if ins.op == "dot":
                cost.flops += cm * _dot_flops(ins, symbols)
            elif ins.op == "convolution":
                # rough: 2 * |result| * (kernel numel / out-channels)
                res_numel, _ = _shape_numel_bytes(ins.shape)
                cost.flops += cm * 2.0 * res_numel
            elif ins.op in ("reduce", "reduce-window", "sort"):
                opnds = _OPERAND_RE.findall(ins.line.split("(", 1)[1])
                if opnds and opnds[0] in symbols:
                    n, _ = _shape_numel_bytes(symbols[opnds[0]])
                    cost.flops += cm * n
            elif ins.op not in _ZERO_FLOP_OPS:
                dt = ins.shape.split("[")[0].lstrip("(")
                n, _ = _shape_numel_bytes(ins.shape)
                cost.flops += cm * n
            # ---- bytes (skip fusion internals) ----
            if not in_fusion and ins.op not in _DATA_MOVEMENT:
                argstr = ins.line.split("(", 1)[1] if "(" in ins.line else ""
                argstr = argstr.split("), ")[0]
                opnds = _OPERAND_RE.findall(argstr)
                _, res_b = _shape_numel_bytes(ins.shape)
                if ins.op in _SLICE_READ_OPS:
                    cost.bytes += cm * 2 * res_b
                elif ins.op in _UPDATE_OPS:
                    idx = _UPDATE_OPS[ins.op]
                    upd_b = res_b
                    if idx < len(opnds) and opnds[idx] in symbols:
                        _, upd_b = _shape_numel_bytes(symbols[opnds[idx]])
                    cost.bytes += cm * 2 * upd_b
                else:
                    op_b = 0
                    for opn in opnds:
                        if opn in symbols:
                            _, b = _shape_numel_bytes(symbols[opn])
                            op_b += b
                    if ins.op == "fusion":
                        total = _fusion_effective_bytes(
                            ins, comps, symbols, comp_params, res_b, opnds)
                    else:
                        total = res_b + op_b
                    cost.bytes += cm * total
            # ---- collectives ----
            if base_op in _COLLECTIVES and not ins.op.endswith("-done"):
                _, size = _shape_numel_bytes(ins.shape)
                n = _group_size(ins.line, total_devices)
                if n <= 1:
                    continue
                if base_op == "all-reduce":
                    wire = 2.0 * (n - 1) / n * size
                elif base_op == "reduce-scatter":
                    wire = float(n - 1) * size
                elif base_op == "collective-permute":
                    wire = float(size)
                else:
                    wire = (n - 1) / n * size
                cost.coll_bytes_by_kind[base_op] += wire * cm
                cost.coll_count_by_kind[base_op] += int(cm)
    cost.collective_bytes = sum(cost.coll_bytes_by_kind.values())
    return cost


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    """Three roofline terms in seconds (per-device quantities = HLO_global /
    chips, matching the task formulas)."""
    return {
        "t_compute": flops_per_dev / PEAK_FLOPS,
        "t_memory": bytes_per_dev / HBM_BW,
        "t_collective": coll_bytes_per_dev / (ICI_BW * ICI_LINKS),
    }


def dominant_term(terms: dict) -> str:
    key = max(("t_compute", "t_memory", "t_collective"), key=lambda k: terms[k])
    return {"t_compute": "compute", "t_memory": "memory",
            "t_collective": "collective"}[key]


# ---------------------------------------------------------------------------
# structural denoiser roofline (fused vs naive dit_apply)
# ---------------------------------------------------------------------------

def denoiser_cost(dc, batch: int, image_size: int, channels: int = 3, *,
                  fused: bool = False, bf16: bool = False) -> dict:
    """Structural FLOP/byte model of ONE ``dit_apply`` call.

    Counts the documented dominant terms — matmul traffic, attention
    traffic, and the LN+modulation sites — for the naive einsum denoiser
    vs the Pallas-fused one (kernels/flash_attention + kernels/adaln_norm).
    FLOPs are identical across the two (fusion changes WHERE intermediates
    live, not the arithmetic); bytes differ:

    * attention — naive materialises the (B, h, S, S) logits and probs in
      HBM (logits write + softmax read/write + prob read for the PV
      matmul = 4 S² passes, fp32); fused streams K/V blocks through VMEM
      with online softmax, so only q/k/v reads and the o write remain;
    * LN sites — naive takes ~3 HBM passes over the (B, S, d) tokens per
      site (stats read, normalise read, modulated write); the fused
      kernel takes 2 (read + write), one VMEM pass;
    * ``bf16`` halves the QKV/MLP matmul operand traffic (activations and
      weights move as bf16; accumulation stays fp32 on the MXU).

    Residual adds, patchify/unpatchify reshapes and the tiny conditioning
    MLP are identical on both paths and omitted.  Returns
    ``{"flops", "bytes", "intensity"}`` (global, one call).
    """
    B, d, L = batch, dc.d_model, dc.num_layers
    h, p = dc.num_heads, dc.patch
    n_tok = (image_size // p) ** 2
    S = n_tok + 1
    pd = p * p * channels
    ff = 4 * d
    f32 = 4
    act = 2 if (fused and bf16) else 4

    # -- FLOPs (2·M·N·K per matmul; same fused or naive) --
    flops = 2.0 * B * n_tok * pd * d                  # patch_in
    flops += 2.0 * B * (2 * d * d + 2 * dc.cond_dim * d)  # cond MLP + y maps
    per_layer = (2.0 * B * d * 6 * d                  # adaLN modulation
                 + 2.0 * B * S * d * 3 * d            # qkv
                 + 2.0 * 2 * B * S * S * d            # qk^T + pv
                 + 2.0 * B * S * d * d                # wo
                 + 2.0 * 2 * B * S * d * ff)          # mlp up + down
    flops += L * per_layer
    flops += 2.0 * B * d * 2 * d + 2.0 * B * n_tok * d * pd  # out head

    # -- HBM bytes --
    tok = B * S * d                                   # one token tensor
    # matmul operand/result traffic (per layer)
    mm = ((tok + 3 * d * d + 3 * tok)                 # qkv
          + (tok + d * d + tok)                       # wo
          + (tok + 4 * d * d + 4 * tok)               # mlp up
          + (4 * tok + 4 * d * d + tok)) * act        # mlp down
    mm += (B * d + 6 * d * d + 6 * B * d) * f32       # modulation (fp32)
    # attention traffic
    attn_io = (3 * tok + tok) * f32                   # q/k/v read + o write
    s2 = B * h * S * S * f32
    attn = attn_io + (0 if fused else 4 * s2)
    # LN+modulation sites: 2 per layer (+1 final, counted below)
    ln_passes = 2 if fused else 3
    ln = 2 * ln_passes * tok * f32
    bytes_ = L * (mm + attn + ln)
    bytes_ += ln_passes * B * n_tok * d * f32         # final LN site
    bytes_ += (B * n_tok * pd + pd * d + B * n_tok * d) * f32   # patch_in
    bytes_ += (B * n_tok * d + d * pd + B * n_tok * pd) * f32   # patch_out
    return {"flops": flops, "bytes": float(bytes_),
            "intensity": flops / bytes_}
