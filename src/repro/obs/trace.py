"""Span tracing for the serving stack.

``Tracer`` records NESTABLE SPANS — named intervals with a monotonic
start, a duration, and structured attributes — plus per-request
LIFECYCLE STAMPS, so a drain's timeline (packing, dispatch, fenced
device scans, store I/O) and every request's queue-wait / end-to-end
latency fall out of one object:

* ``with tracer.span("wave.sample", host=h, wave=k): ...`` opens a span;
  nesting is tracked (``Span.depth``), attributes may be added while the
  span is open via ``.set(...)``, and the clock is INJECTABLE — tests run
  drains under a ``FakeClock`` and assert exact timings;
* ``tracer.stamp(rid, "admit")`` stamps one stage of a request's
  lifecycle (``admit → enqueue → pack → dispatch → retire → deliver``;
  first stamp per (rid, stage) wins, so a request whose rows span
  several waves keeps its FIRST pack/dispatch);
  ``tracer.request_latency(rid)`` derives ``queue_wait``
  (enqueue → dispatch) and ``e2e_latency`` (admit → deliver) from them;
* a DISABLED tracer (``Tracer(enabled=False)``, the engine default) is
  near-zero cost: ``span()`` returns one shared no-op context manager
  and ``stamp`` returns immediately — nothing is recorded, no clock is
  read, and the serving hot path stays untimed.

Tracing NEVER touches computation: spans and stamps observe the drain,
they do not key noise, schedule waves, or order anything — D_syn is
bit-identical with tracing on or off (gated in ``tests/test_obs.py`` and
the benchmark's ``--mode trace`` CI step).

THREAD-SAFETY: the engine's per-host drain workers open spans and stamp
lifecycles concurrently.  Span NESTING is tracked per thread (each
thread sees its own depth stack — a worker's ``device.scan`` nests
under whatever that worker opened, never under another host's span),
while the closed-span buffer and the lifecycle stamps are guarded by
one lock so no record is lost.  The disabled path is untouched:
``span()`` still returns the shared no-op and ``stamp`` still returns
before reading any clock or taking any lock.

Export to a Perfetto/``chrome://tracing``-loadable timeline lives in
``obs/export.py``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

#: request-lifecycle stages, in order.  ``stamp`` accepts only these.
LIFECYCLE_STAGES = ("admit", "enqueue", "pack", "dispatch", "retire",
                    "deliver")
_STAGE_SET = frozenset(LIFECYCLE_STAGES)


class FakeClock:
    """Deterministic injectable clock: returns a fixed time until
    ``advance``d.  ``tick`` (optional) auto-advances by a fixed step on
    every read, so consecutive spans get distinct, predictable stamps.
    Reads and advances are atomic (its own lock): concurrent drain
    workers reading a ticking clock must not tear the increment."""

    def __init__(self, start: float = 0.0, *, tick: float = 0.0):
        self.t = float(start)
        self.tick = float(tick)
        self._lock = threading.Lock()

    def advance(self, dt: float):
        with self._lock:
            self.t += float(dt)

    def __call__(self) -> float:
        with self._lock:
            now = self.t
            self.t += self.tick
            return now


@dataclass
class Span:
    """One closed span: ``start`` / ``duration`` are seconds on the
    tracer's clock; ``depth`` is the nesting level at open time (0 =
    top-level); ``attrs`` are the structured attributes (``host=`` puts
    the span on that host's track in the exported timeline)."""
    name: str
    start: float
    duration: float
    attrs: dict = field(default_factory=dict)
    depth: int = 0

    @property
    def end(self) -> float:
        return self.start + self.duration


class _NullSpan:
    """Shared no-op context manager — the whole disabled-tracer span
    path is two attribute loads and one call."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class _OpenSpan:
    """A span being recorded; closes (and appends to the tracer) on
    ``__exit__``."""
    __slots__ = ("_tracer", "name", "attrs", "_start", "depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        stack = self._tracer._stack   # this THREAD's nesting stack
        self.depth = len(stack)
        stack.append(self)
        self._start = self._tracer.clock()
        return self

    def __exit__(self, *exc):
        end = self._tracer.clock()
        stack = self._tracer._stack
        if stack and stack[-1] is self:
            stack.pop()
        else:                            # exited out of order: drop to self
            while stack and stack[-1] is not self:
                stack.pop()
            if stack:
                stack.pop()
        with self._tracer._lock:
            self._tracer.spans.append(Span(self.name, self._start,
                                           max(end - self._start, 0.0),
                                           self.attrs, self.depth))
        return False


class Tracer:
    """Span + request-lifecycle recorder.

    ``clock`` is any zero-arg callable returning seconds on a monotonic
    scale (default ``time.perf_counter``; tests inject ``FakeClock``).
    ``enabled=False`` makes every recording call a near-zero-cost no-op.
    """

    def __init__(self, *, clock: Optional[Callable[[], float]] = None,
                 enabled: bool = True):
        self.clock = clock if clock is not None else time.perf_counter
        self.enabled = enabled
        self.spans: list[Span] = []
        self.lifecycle: dict[int, dict[str, float]] = {}
        self._tls = threading.local()    # per-thread nesting stacks
        self._lock = threading.Lock()    # guards spans + lifecycle

    @property
    def _stack(self) -> list:
        """The CALLING thread's open-span stack: nesting depth is a
        per-thread notion (a drain worker's spans nest under what that
        worker opened, not under another host's)."""
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    # -- spans ------------------------------------------------------------
    def span(self, name: str, **attrs):
        """Open a nestable span: ``with tracer.span("wave.pack", wave=3,
        host=0) as sp: ... sp.set(rows=64)``."""
        if not self.enabled:
            return NULL_SPAN
        return _OpenSpan(self, name, attrs)

    def instant(self, name: str, **attrs):
        """Record a zero-duration marker at the current clock."""
        if not self.enabled:
            return
        span = Span(name, self.clock(), 0.0, attrs, len(self._stack))
        with self._lock:
            self.spans.append(span)

    def now(self) -> Optional[float]:
        """Current clock reading, or None when disabled — how the engine
        captures a timestamp early (e.g. at pack time) to commit as a
        stamp later, once the wave it belongs to actually dispatched."""
        return self.clock() if self.enabled else None

    # -- request lifecycle ------------------------------------------------
    def stamp(self, rid: int, stage: str, t: Optional[float] = None):
        """Stamp one lifecycle stage for request ``rid``.  First stamp
        per (rid, stage) wins — a request whose rows span several waves
        keeps its first pack/dispatch time.  ``t`` (from ``now()``)
        backdates the stamp to a previously captured clock reading, so a
        stage observed mid-wave can be committed only after the wave
        succeeds (an aborted wave must not freeze its stamps)."""
        if not self.enabled:
            return
        if stage not in _STAGE_SET:
            raise ValueError(f"unknown lifecycle stage {stage!r}; expected "
                             f"one of {LIFECYCLE_STAGES}")
        if t is None:
            t = self.clock()
        with self._lock:
            self.lifecycle.setdefault(rid, {}).setdefault(stage, t)

    def request_latency(self, rid: int) -> dict:
        """Derived latencies for ``rid``: ``queue_wait`` (enqueue →
        dispatch — time spent on an ingress queue before any of its rows
        hit a device) and ``e2e_latency`` (admit → deliver).  Missing
        stages (e.g. a pure cache hit never enqueues) simply omit the
        corresponding entry."""
        st = self.lifecycle.get(rid)
        if not st:
            return {}
        out = {}
        if "enqueue" in st and "dispatch" in st:
            out["queue_wait"] = st["dispatch"] - st["enqueue"]
        if "admit" in st and "deliver" in st:
            out["e2e_latency"] = st["deliver"] - st["admit"]
        return out

    # -- management -------------------------------------------------------
    def clear(self):
        self.spans.clear()
        self.lifecycle.clear()
        self._stack.clear()

    def __repr__(self):
        return (f"Tracer(enabled={self.enabled}, spans={len(self.spans)}, "
                f"requests={len(self.lifecycle)})")
