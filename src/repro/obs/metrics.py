"""Metrics registry: counters, gauges, and fixed-bucket latency
histograms with quantile readout.

``MetricsRegistry`` is the one observability idiom behind every serving
``stats`` dict — the engines bump named counters (optionally LABELLED,
e.g. ``inc("host.rows", host=h)`` for the per-host breakdown) and expose
a backward-compatible dict VIEW built from the registry, so existing
tests, benchmarks, and gates read bit-identical values while new
consumers get typed metrics and latency quantiles.

Histograms are FIXED-BUCKET (geometric edges, default 8 buckets per
decade from 100 ns to 1000 s): observation cost is one bisect + one
increment, memory is constant, and ``quantile(q)`` reads p50/p90/p99 by
linear interpolation inside the covering bucket — the estimate is
guaranteed to land within the true quantile's bucket (≤ ~33 % relative
error at the default resolution; ``tests/test_obs.py`` gates this
against a numpy oracle).

THREAD-SAFETY: the registry's write paths (``inc``/``set_gauge``/
``observe``) and its read/maintenance paths take one internal lock —
the engine's per-host drain workers bump counters concurrently, and a
bare ``self.value += v`` is a read-modify-write that drops increments
under interleaving.  The lock is per-OPERATION (a wave bumps a handful
of counters, never one per sample), so the serialized section is a few
dict lookups and an add.  Metric handles returned by ``counter()``/
``gauge()``/``histogram()`` are NOT individually locked — mutate
through the registry when more than one thread writes.
"""
from __future__ import annotations

import threading
from bisect import bisect_right

import numpy as np


def default_buckets() -> tuple:
    """Geometric latency-bucket edges: 8 per decade, 1e-7 s … 1e3 s."""
    return tuple(float(10.0 ** (-7 + i / 8)) for i in range(81))


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, v=1):
        self.value += v

    def get(self):
        return self.value


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v):
        self.value = v

    def get(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max and
    interpolated quantiles."""
    __slots__ = ("edges", "counts", "count", "sum", "min", "max")

    def __init__(self, buckets=None):
        edges = tuple(buckets) if buckets is not None else default_buckets()
        if len(edges) < 2 or any(b <= a for a, b in zip(edges, edges[1:])):
            raise ValueError("histogram buckets must be >= 2 strictly "
                             "increasing edges")
        self.edges = edges
        # bucket i holds values in (edges[i-1], edges[i]]; bucket 0 is the
        # underflow (-inf, edges[0]], the last is overflow (edges[-1], inf)
        self.counts = [0] * (len(edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v):
        v = float(v)
        self.counts[bisect_right(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, q: float) -> float:
        """Rank-``q`` value estimate: locate the covering bucket, then
        interpolate linearly inside it (clamped to the observed min/max,
        so under- and overflow buckets stay finite)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return float("nan")
        rank = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if acc + c >= rank:
                lo = self.edges[i - 1] if 0 < i <= len(self.edges) \
                    else self.min
                hi = self.edges[i] if i < len(self.edges) else self.max
                lo, hi = max(lo, self.min), min(hi, self.max)
                frac = (rank - acc) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            acc += c
        return self.max

    def percentiles(self) -> dict:
        return {"p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}

    def summary(self) -> dict:
        s = {"count": self.count, "sum": self.sum}
        if self.count:
            s.update(min=self.min, max=self.max,
                     mean=self.sum / self.count, **self.percentiles())
        return s


class MetricsRegistry:
    """Named, optionally labelled counters/gauges/histograms.

    ``inc``/``set_gauge``/``observe`` auto-create on first use; ``get``
    reads a raw value (0 / NaN-free default for an absent metric);
    ``drop(prefix)`` removes every metric whose name starts with
    ``prefix`` (how the engine resets the per-host breakdown when its
    topology is swapped); ``as_dict`` is the flat JSON-able dump
    ``obs/export.py`` writes next to a trace."""

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        # one lock over create + mutate: per-host drain workers write
        # concurrently and counter increments are read-modify-write
        self._lock = threading.Lock()

    @staticmethod
    def _key(name, labels):
        return (name, tuple(sorted(labels.items())))

    def _get_or_make(self, name, labels, cls, *args):
        # callers hold self._lock
        key = self._key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(*args)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r}{labels or ''} is "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    # -- typed accessors (create on first use) ----------------------------
    def counter(self, name, **labels) -> Counter:
        with self._lock:
            return self._get_or_make(name, labels, Counter)

    def gauge(self, name, **labels) -> Gauge:
        with self._lock:
            return self._get_or_make(name, labels, Gauge)

    def histogram(self, name, buckets=None, **labels) -> Histogram:
        with self._lock:
            return self._get_or_make(name, labels, Histogram, buckets)

    # -- convenience write/read paths -------------------------------------
    def inc(self, name, value=1, **labels):
        with self._lock:
            self._get_or_make(name, labels, Counter).inc(value)

    def set_gauge(self, name, value, **labels):
        with self._lock:
            self._get_or_make(name, labels, Gauge).set(value)

    def observe(self, name, value, **labels):
        with self._lock:
            self._get_or_make(name, labels, Histogram, None).observe(value)

    def get(self, name, default=0, **labels):
        with self._lock:
            m = self._metrics.get(self._key(name, labels))
            return default if m is None else m.get() if not isinstance(
                m, Histogram) else m.summary()

    def drop(self, prefix: str):
        """Remove every metric whose name starts with ``prefix``."""
        with self._lock:
            for key in [k for k in self._metrics
                        if k[0].startswith(prefix)]:
                del self._metrics[key]

    def as_dict(self) -> dict:
        """Flat dump: ``name`` or ``name{k=v,...}`` → value (histograms
        dump their summary incl. p50/p90/p99)."""
        out = {}
        with self._lock:
            items = sorted(self._metrics.items(),
                           key=lambda kv: (kv[0][0], str(kv[0][1])))
        for (name, labels), m in items:
            qual = name if not labels else (
                name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}")
            out[qual] = (m.summary() if isinstance(m, Histogram)
                         else m.get())
        return out
