"""Chrome trace-event export: drain timelines Perfetto can load.

``chrome_trace`` turns a ``Tracer``'s spans into the Chrome trace-event
JSON format (https://ui.perfetto.dev or ``chrome://tracing`` load it
directly): one complete event (``ph="X"``) per span with microsecond
``ts``/``dur``, plus thread-name metadata so the timeline shows ONE
TRACK PER HOST:

* spans carrying ``host=h`` land on the ``host h`` track — under a
  simulated topology the per-window pack/dispatch/fence spans line up
  per host, which is exactly the lens the "make multi-host actually
  concurrent" ROADMAP item needs (sequential windows show as
  non-overlapping blocks today; a real executor must make them overlap);
* spans carrying ``track="store"`` (shard read/write/flush I/O) get a
  dedicated store track;
* everything else (drain, admission, wave packing for the single-host
  path) sits on the scheduler track.

``metrics_json`` dumps a ``MetricsRegistry`` flat (counters, gauges,
histogram summaries with p50/p90/p99) and ``validate_chrome_trace``
checks the schema CI gates: required keys per event, non-negative
timestamps/durations, and every span inside the drain bounds.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

_SCHEDULER_TID = 0
_HOST_TID_BASE = 1            # host h → tid 1 + h
_STORE_TRACK = "store"

REQUIRED_EVENT_KEYS = ("ph", "ts", "pid", "tid", "name")


def _tid(span_attrs: dict, num_hosts: int) -> int:
    if span_attrs.get("track") == _STORE_TRACK:
        return _HOST_TID_BASE + num_hosts          # after the host tracks
    host = span_attrs.get("host")
    if host is not None:
        return _HOST_TID_BASE + int(host)
    return _SCHEDULER_TID


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


def chrome_trace(tracer: Tracer, *, hosts: int | None = None,
                 pid: int = 0, process_name: str = "synthesis-server",
                 ) -> dict:
    """Build the trace-event JSON object for ``tracer``'s spans.

    ``hosts`` forces at least that many host tracks (a drain that never
    placed a wave still shows its topology); otherwise tracks are
    derived from the ``host=`` attributes seen.  Timestamps are the
    tracer clock converted to integer-rounded microseconds."""
    seen = {int(s.attrs["host"]) for s in tracer.spans
            if s.attrs.get("host") is not None}
    num_hosts = max(hosts or 0, max(seen) + 1 if seen else 0)
    has_store = any(s.attrs.get("track") == _STORE_TRACK
                    for s in tracer.spans)

    events = [{"ph": "M", "pid": pid, "tid": 0, "ts": 0,
               "name": "process_name", "args": {"name": process_name}},
              {"ph": "M", "pid": pid, "tid": _SCHEDULER_TID, "ts": 0,
               "name": "thread_name", "args": {"name": "scheduler"}}]
    for h in range(num_hosts):
        events.append({"ph": "M", "pid": pid, "tid": _HOST_TID_BASE + h,
                       "ts": 0, "name": "thread_name",
                       "args": {"name": f"host {h}"}})
    if has_store:
        events.append({"ph": "M", "pid": pid,
                       "tid": _HOST_TID_BASE + num_hosts, "ts": 0,
                       "name": "thread_name", "args": {"name": "store"}})

    for s in tracer.spans:
        events.append({
            "ph": "X", "pid": pid, "tid": _tid(s.attrs, num_hosts),
            "ts": round(s.start * 1e6, 3),
            "dur": round(s.duration * 1e6, 3),
            "name": s.name,
            "args": {k: _jsonable(v) for k, v in s.attrs.items()
                     if k not in ("track",)},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def metrics_json(registry: MetricsRegistry) -> dict:
    """Flat JSON-able metrics dump (counters/gauges raw, histograms as
    count/sum/min/max/mean/p50/p90/p99 summaries)."""
    return registry.as_dict()


def write_trace(path, tracer: Tracer, *,
                registry: MetricsRegistry | None = None,
                hosts: int | None = None) -> dict:
    """Export ``tracer`` (and optionally a metrics dump) to ``path``.
    Validates the trace before writing, so a malformed export fails the
    producer, not the eventual Perfetto load."""
    obj = chrome_trace(tracer, hosts=hosts)
    if registry is not None:
        obj["metrics"] = metrics_json(registry)
    validate_chrome_trace(obj, require_hosts=hosts)
    Path(path).write_text(json.dumps(obj, indent=1))
    return obj


def validate_chrome_trace(obj: dict, *, require_hosts: int | None = None):
    """Schema gate for exported traces (the CI smoke step runs this on
    the benchmark artifact).  Checks:

    * ``traceEvents`` is a list and every event carries ``ph/ts/pid/tid/
      name`` (complete events additionally ``dur``);
    * timestamps and durations are non-negative numbers;
    * every span lies within the drain bounds (the earliest span start /
      latest span end — a span outside them means a clock went
      backwards or an export mixed clocks);
    * at least ``require_hosts`` named host tracks exist.

    Raises ``ValueError`` naming every violation; returns the event
    count when clean."""
    errors = []
    events = obj.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("trace has no traceEvents list")
    spans = [e for e in events if e.get("ph") == "X"]
    for i, e in enumerate(events):
        for k in REQUIRED_EVENT_KEYS:
            if k not in e:
                errors.append(f"event {i} ({e.get('name')!r}) missing {k!r}")
        if e.get("ph") == "X":
            if "dur" not in e:
                errors.append(f"span {i} ({e.get('name')!r}) missing 'dur'")
            elif not (isinstance(e["dur"], (int, float)) and e["dur"] >= 0):
                errors.append(f"span {i} ({e.get('name')!r}) has negative "
                              f"or non-numeric dur {e['dur']!r}")
        ts = e.get("ts")
        if ts is not None and not (isinstance(ts, (int, float)) and ts >= 0):
            errors.append(f"event {i} ({e.get('name')!r}) has negative or "
                          f"non-numeric ts {ts!r}")
    if spans:
        ok = [e for e in spans if isinstance(e.get("ts"), (int, float))
              and isinstance(e.get("dur"), (int, float))]
        if ok:
            lo = min(e["ts"] for e in ok)
            hi = max(e["ts"] + e["dur"] for e in ok)
            for e in ok:
                if e["ts"] < lo or e["ts"] + e["dur"] > hi:
                    errors.append(f"span {e['name']!r} outside drain "
                                  f"bounds [{lo}, {hi}]")
    else:
        errors.append("trace has no complete ('X') span events")
    if require_hosts:
        tracks = {e["args"]["name"] for e in events
                  if e.get("ph") == "M" and e.get("name") == "thread_name"
                  and isinstance(e.get("args"), dict)
                  and "name" in e["args"]}
        missing = [f"host {h}" for h in range(require_hosts)
                   if f"host {h}" not in tracks]
        if missing:
            errors.append(f"missing host tracks: {missing} "
                          f"(have {sorted(tracks)})")
    if errors:
        raise ValueError("invalid chrome trace: " + "; ".join(errors))
    return len(events)
