"""Observability for the serving stack: span tracing (``obs/trace.py``),
typed metrics with latency quantiles (``obs/metrics.py``), and
Perfetto-loadable timeline export (``obs/export.py``)."""
from repro.obs.export import (chrome_trace, metrics_json,
                              validate_chrome_trace, write_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_buckets)
from repro.obs.trace import (LIFECYCLE_STAGES, FakeClock, Span, Tracer,
                             NULL_SPAN)
