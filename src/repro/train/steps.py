"""Train-step factory for the LM zoo (used by the launcher and dry-run)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.moe import Parallel
from repro.models.transformer import init_lm, loss_fn
from repro.optim import adamw, apply_updates, clip_by_global_norm, init_adamw
from repro.optim.optimizers import AdamWState


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


def init_train_state(key, cfg: ModelConfig) -> TrainState:
    params = init_lm(key, cfg)
    return TrainState(params, init_adamw(params))


def make_train_step(cfg: ModelConfig, par: Parallel = Parallel(), *,
                    lr=3e-4, weight_decay: float = 0.1,
                    clip_norm: float = 1.0):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, par), has_aux=True)(state.params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt = adamw(grads, state.opt, state.params, lr=lr,
                             weight_decay=weight_decay)
        params = apply_updates(state.params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(params, opt), metrics

    return train_step
