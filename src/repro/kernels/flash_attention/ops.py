"""Public wrapper: (B, S, H, hd) layout, padding to block multiples, GQA,
CPU interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, interpret: bool | None = None):
    """q: (B, Sq, Hq, hd); k/v: (B, Sk, Hkv, hd) → (B, Sq, Hq, hd)."""
    if interpret is None:
        interpret = _on_cpu()
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    # Blocks rounded up to the 8-row sublane multiple: an S = n_tok+1
    # sequence (odd by construction — e.g. 17, 65 from the DiT's prepended
    # conditioning token) pads to an aligned block instead of launching a
    # misaligned one; the kernel masks the padded K rows via true_sk.
    blk_q = min(K.DEFAULT_BLOCK_Q, max(8, -(-Sq // 8) * 8))
    blk_k = min(K.DEFAULT_BLOCK_K, max(8, -(-Sk // 8) * 8))
    pad_q = (-Sq) % blk_q
    pad_k = (-Sk) % blk_k
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = K.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                 softcap=softcap, blk_q=blk_q, blk_k=blk_k,
                                 interpret=interpret, true_sk=Sk)
    if pad_q:
        out = out[:, :, :Sq, :]
    return out.transpose(0, 2, 1, 3)
