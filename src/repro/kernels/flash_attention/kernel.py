"""Pallas TPU flash attention (forward).

Canonical online-softmax blocking re-thought for the MXU/VMEM hierarchy
(DESIGN.md §4): 128-aligned Q/KV blocks stream through VMEM; the running
(m, l, acc) state lives in VMEM scratch and persists across the sequential
kv-block grid axis.  Supports the zoo's variants: GQA (q-head → kv-head
mapping in the index maps), causal masks, sliding windows (gemma2 local
layers), attention-logit softcap (gemma2), encoder (non-causal) mode.

Grid: (B, H_q, n_q_blocks, n_kv_blocks) — the last axis is 'arbitrary'
(sequential); fully-masked kv blocks are skipped with pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, softcap, blk_q, blk_k, nk, sq, sk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    kpos = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)

    # block-level skip: any work in this (iq, ik) tile?
    needed = jnp.bool_(True)
    if causal:
        needed &= (ik * blk_k) <= (iq * blk_q + blk_q - 1)
    if window:
        needed &= (ik * blk_k + blk_k - 1) > (iq * blk_q - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (blk_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (blk_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_scr[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_scr[...][:, 0] + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new[:, None]
        l_scr[...] = l_new[:, None]

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...][:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "blk_q", "blk_k",
                     "interpret", "true_sk"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         softcap: float = 0.0, blk_q: int = DEFAULT_BLOCK_Q,
                         blk_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False, true_sk: int | None = None):
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd); Sq, Sk padded to blocks
    by the ops wrapper.  Returns (B, Hq, Sq, hd)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    nq = pl.cdiv(Sq, blk_q)
    nk = pl.cdiv(Sk, blk_k)
    scale = hd ** -0.5

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, blk_q=blk_q, blk_k=blk_k, nk=nk, sq=Sq,
        sk=true_sk or Sk)

    return pl.pallas_call(
        kern,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, blk_k, hd), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        # jax < 0.5 exposes the TPU params as TPUCompilerParams
        compiler_params=getattr(pltpu, "CompilerParams",
                                getattr(pltpu, "TPUCompilerParams", None))(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
