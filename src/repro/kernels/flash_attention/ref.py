"""Pure-jnp oracle for the flash-attention kernel: plain materialised
softmax attention with the zoo's mask/softcap variants.

Layout contract (kernel + oracle): q,k,v are (B, H, S, hd); GQA is
expressed by H_q = rep · H_kv with k/v already *unrepeated* — the oracle
repeats explicitly."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0):
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
