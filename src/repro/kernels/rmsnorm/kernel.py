"""Pallas TPU fused RMSNorm: one VMEM pass (reduce + normalise + scale)
instead of separate square/mean/rsqrt/mul HBM round-trips.

Tiling: rows blocked (block_rows, d) — d stays whole so the row reduction
is VMEM-local; model dims in the zoo (768..8192) fit comfortably."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _rms_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_2d(x, scale, *, eps: float = 1e-6, interpret: bool = False):
    """x: (rows, d); scale: (d,)."""
    rows, d = x.shape
    block = min(BLOCK_ROWS, rows)
    return pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(pl.cdiv(rows, block),),
        in_specs=[pl.BlockSpec((block, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale)
