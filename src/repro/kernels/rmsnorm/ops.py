"""Public wrapper for the fused RMSNorm kernel: arbitrary leading dims,
row padding, CPU interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rmsnorm import kernel as K


def rmsnorm(x, scale, eps: float = 1e-6, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    shape = x.shape
    d = shape[-1]
    rows = int(np.prod(shape[:-1]))
    xf = x.reshape(rows, d)
    pad = (-rows) % 8
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = K.rmsnorm_2d(xf, scale, eps=eps, interpret=interpret)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
