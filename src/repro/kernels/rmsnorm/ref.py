"""Pure-jnp oracle for the fused RMSNorm kernel ((1+scale) convention,
matching ``repro.models.layers.rmsnorm``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)
