"""Pallas TPU kernel: fused CFG guidance-combine + ancestral update.

On GPU implementations (diffusers etc.) this is a chain of ~10 elementwise
HBM round-trips; here it is ONE VMEM-resident pass over (x, ε_c, ε_u, z).
Tiling: inputs flattened to (rows, 128) lanes, 8-row sublane alignment,
(256, 128) VMEM blocks.  The per-step schedule constants (ᾱ_t, ᾱ_prev) are
traced scalars carried in SMEM; the guidance scale s and η are static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BLOCK_ROWS = 256


def _cfg_kernel(scal_ref, x_ref, ec_ref, eu_ref, z_ref, out_ref, *, s, eta):
    ab_t = scal_ref[0]
    ab_prev = scal_ref[1]
    x = x_ref[...].astype(jnp.float32)
    eps = (1.0 + s) * ec_ref[...].astype(jnp.float32) \
        - s * eu_ref[...].astype(jnp.float32)
    x0 = (x - jnp.sqrt(1.0 - ab_t) * eps) * jax.lax.rsqrt(ab_t)
    x0 = jnp.clip(x0, -1.0, 1.0)
    var = (1.0 - ab_prev) / (1.0 - ab_t) * (1.0 - ab_t / ab_prev)
    sigma = eta * jnp.sqrt(jnp.maximum(var, 0.0))
    dir_coef = jnp.sqrt(jnp.maximum(1.0 - ab_prev - sigma * sigma, 0.0))
    out = jnp.sqrt(ab_prev) * x0 + dir_coef * eps \
        + sigma * z_ref[...].astype(jnp.float32)
    out_ref[...] = out.astype(out_ref.dtype)


def _cfg_rowwise_kernel(off_ref, scal_ref, x_ref, ec_ref, eu_ref, z_ref,
                        out_ref, *, eta):
    # segment-offset indexing: tensor row b reads its scalars at column
    # off + b of a scalar table that may span a WIDER row range than this
    # launch — a compaction segment (or a per-host window of a sharded
    # wave) addresses its window of the wave-resident (4, B_wave) table
    # instead of materialising a sliced copy per segment per step.
    b = off_ref[0] + pl.program_id(0)
    ab_t = scal_ref[0, b]
    ab_prev = scal_ref[1, b]
    s = scal_ref[2, b]
    act = scal_ref[3, b]
    x = x_ref[...].astype(jnp.float32)
    eps = (1.0 + s) * ec_ref[...].astype(jnp.float32) \
        - s * eu_ref[...].astype(jnp.float32)
    x0 = (x - jnp.sqrt(1.0 - ab_t) * eps) * jax.lax.rsqrt(ab_t)
    x0 = jnp.clip(x0, -1.0, 1.0)
    var = (1.0 - ab_prev) / (1.0 - ab_t) * (1.0 - ab_t / ab_prev)
    sigma = eta * jnp.sqrt(jnp.maximum(var, 0.0))
    dir_coef = jnp.sqrt(jnp.maximum(1.0 - ab_prev - sigma * sigma, 0.0))
    out = jnp.sqrt(ab_prev) * x0 + dir_coef * eps \
        + sigma * z_ref[...].astype(jnp.float32)
    out = jnp.where(act > 0.0, out, x)
    out_ref[...] = out.astype(out_ref.dtype)


def _cfg_mixed_kernel(off_ref, scal_ref, x_ref, ec_ref, eu_ref, z_ref,
                      out_ref, *, eta):
    # mixed-guidance row: the (5, Bs) scalar table carries one
    # (mode, ᾱ_t, ᾱ_prev, s, active) tuple per wave row.  mode selects
    # the guidance combine — 0 is the cfg pair-combine (uncond rides it
    # as s=0 with a null cond row), 1 takes ε_c as the classifier-
    # corrected ε̂ computed upstream.  Same segment-offset indexing as
    # the pure-cfg rowwise kernel: tensor row b reads column off + b.
    b = off_ref[0] + pl.program_id(0)
    mode = scal_ref[0, b]
    ab_t = scal_ref[1, b]
    ab_prev = scal_ref[2, b]
    s = scal_ref[3, b]
    act = scal_ref[4, b]
    x = x_ref[...].astype(jnp.float32)
    ec = ec_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    eps = jnp.where(mode < 0.5, (1.0 + s) * ec - s * eu, ec)
    x0 = (x - jnp.sqrt(1.0 - ab_t) * eps) * jax.lax.rsqrt(ab_t)
    x0 = jnp.clip(x0, -1.0, 1.0)
    var = (1.0 - ab_prev) / (1.0 - ab_t) * (1.0 - ab_t / ab_prev)
    sigma = eta * jnp.sqrt(jnp.maximum(var, 0.0))
    dir_coef = jnp.sqrt(jnp.maximum(1.0 - ab_prev - sigma * sigma, 0.0))
    out = jnp.sqrt(ab_prev) * x0 + dir_coef * eps \
        + sigma * z_ref[...].astype(jnp.float32)
    out = jnp.where(act > 0.0, out, x)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eta", "interpret"))
def cfg_update_mixed_3d(x, eps_c, eps_u, noise, off, scal, *,
                        eta: float = 1.0, interpret: bool = False):
    """Mixed-guidance sibling of ``cfg_update_rowwise_3d``: identical
    grid/layout, but the scalar-prefetch table is (5, Bs) — a per-row
    ``(mode, ᾱ_t, ᾱ_prev, s, active)`` tuple — so cfg, classifier-guided
    and uncond rows share one launch (and one compiled executable)."""
    B, R, _ = x.shape
    block = min(BLOCK_ROWS, R)
    grid = (B, pl.cdiv(R, block))
    kern = functools.partial(_cfg_mixed_kernel, eta=float(eta))
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((1, block, LANES),
                                   lambda b, j, o, s: (b, j, 0))] * 4,
            out_specs=pl.BlockSpec((1, block, LANES),
                                   lambda b, j, o, s: (b, j, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(off, scal, x, eps_c, eps_u, noise)


@functools.partial(jax.jit, static_argnames=("eta", "interpret"))
def cfg_update_rowwise_3d(x, eps_c, eps_u, noise, off, scal, *,
                          eta: float = 1.0, interpret: bool = False):
    """Ragged-wave variant: one grid row per batch element, so every row
    reads its OWN (ᾱ_t, ᾱ_prev, s, active) from the (4, Bs) scalar-prefetch
    array — rows from different (guidance, steps) groups share one kernel
    launch.  Tensor args are pre-laid-out (B, R, 128), R % 8 == 0; a row
    whose ``active`` slot is 0 passes through bit-unchanged.

    ``off`` ((1,) int32 prefetch) is the row-window offset: tensor row b
    reads scalar column ``off + b``, so ``scal`` may carry a whole wave's
    per-row scalars (Bs >= off + B) while this launch updates only a
    window of its rows.  Forward-looking substrate (ROADMAP multi-host):
    today's compaction segments slice their tables host-side up front and
    always call with ``off == 0``; a per-host window of a wave-resident
    table is what needs a non-zero offset."""
    B, R, _ = x.shape
    block = min(BLOCK_ROWS, R)
    grid = (B, pl.cdiv(R, block))
    kern = functools.partial(_cfg_rowwise_kernel, eta=float(eta))
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((1, block, LANES),
                                   lambda b, j, o, s: (b, j, 0))] * 4,
            out_specs=pl.BlockSpec((1, block, LANES),
                                   lambda b, j, o, s: (b, j, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(off, scal, x, eps_c, eps_u, noise)


@functools.partial(jax.jit, static_argnames=("s", "eta", "interpret"))
def cfg_update_2d(x, eps_c, eps_u, noise, ab_t, ab_prev, *, s: float,
                  eta: float = 1.0, interpret: bool = False):
    """All tensor args pre-flattened to (rows, 128), rows % 8 == 0."""
    rows = x.shape[0]
    block = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, block),)
    scal = jnp.stack([ab_t, ab_prev]).astype(jnp.float32)
    kern = functools.partial(_cfg_kernel, s=float(s), eta=float(eta))
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((block, LANES), lambda i, s: (i, 0))] * 4,
            out_specs=pl.BlockSpec((block, LANES), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(scal, x, eps_c, eps_u, noise)
