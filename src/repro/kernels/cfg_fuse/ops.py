"""jit'd public wrapper around the cfg_fuse Pallas kernel: handles
flattening/padding to the (rows, 128) lane layout and CPU interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cfg_fuse import kernel as K


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def cfg_update(x, eps_c, eps_u, s, ab_t, ab_prev, noise, eta: float = 1.0,
               *, interpret: bool | None = None):
    """Fused (1+s)·ε_c − s·ε_u guidance + ancestral update.  Shapes of
    x/eps_c/eps_u/noise are identical and arbitrary; s and eta are static."""
    if interpret is None:
        interpret = _on_cpu()
    shape = x.shape
    n = int(np.prod(shape))
    rows = -(-n // K.LANES)
    rows = -(-rows // 8) * 8
    pad = rows * K.LANES - n

    def flat(a):
        a = a.reshape(-1)
        if pad:
            a = jnp.pad(a, (0, pad))
        return a.reshape(rows, K.LANES)

    out = K.cfg_update_2d(flat(x), flat(eps_c), flat(eps_u), flat(noise),
                          jnp.asarray(ab_t, jnp.float32),
                          jnp.asarray(ab_prev, jnp.float32),
                          s=float(s), eta=float(eta), interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)


def cfg_update_rowwise(x, eps_c, eps_u, s, ab_t, ab_prev, noise, active,
                       eta: float = 1.0, *, row_offset: int = 0,
                       interpret: bool | None = None):
    """Per-row fused update for ragged waves: ``s``/``ab_t``/``ab_prev``/
    ``active`` are (Bs,) vectors — every batch row carries its own guidance
    scale and schedule position, and ``active`` freezes rows whose right-
    aligned trajectory has not started yet.  Each image is flattened to
    its own (rows, 128) lane block so the kernel's per-row scalars apply
    exactly to that image's elements.

    Row-window path: the scalar vectors may be WIDER than ``x``'s batch —
    tensor row b uses scalar slot ``row_offset + b`` — so a window of a
    wave's rows can update against the wave-wide scalar table without
    slicing a copy of it per step.  ``row_offset`` may be a traced scalar
    (the multi-host window path passes it as an operand so one compiled
    executable serves every host offset); the bounds check runs only for
    concrete offsets.  The in-tree compaction scheduler slices its
    segment tables host-side and always uses the default
    ``row_offset=0``."""
    if interpret is None:
        interpret = _on_cpu()
    shape = x.shape
    B = shape[0]
    n = int(np.prod(shape[1:]))
    rows = -(-n // K.LANES)
    rows = -(-rows // 8) * 8
    pad = rows * K.LANES - n

    def flat(a):
        a = a.reshape(B, -1)
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad)))
        return a.reshape(B, rows, K.LANES)

    scal = jnp.stack([
        jnp.asarray(ab_t, jnp.float32).reshape(-1),
        jnp.asarray(ab_prev, jnp.float32).reshape(-1),
        jnp.asarray(s, jnp.float32).reshape(-1),
        jnp.asarray(active).astype(jnp.float32).reshape(-1),
    ])
    if isinstance(row_offset, (int, np.integer)) and \
            (row_offset < 0 or scal.shape[1] < row_offset + B):
        raise ValueError(
            f"rowwise scalars span {scal.shape[1]} rows; window "
            f"[{row_offset}, {row_offset + B}) is out of range")
    off = jnp.asarray(row_offset, jnp.int32).reshape(1)
    out = K.cfg_update_rowwise_3d(flat(x), flat(eps_c), flat(eps_u),
                                  flat(noise), off, scal, eta=float(eta),
                                  interpret=interpret)
    return out.reshape(B, -1)[:, :n].reshape(shape)


def cfg_update_mixed(x, eps_c, eps_u, mode, s, ab_t, ab_prev, noise, active,
                     eta: float = 1.0, *, row_offset: int = 0,
                     interpret: bool | None = None):
    """Per-row MIXED-guidance fused update: like ``cfg_update_rowwise``
    but with a per-row ``mode`` selecting the guidance combine (0 = cfg
    pair-combine, uncond riding it as s=0 null-cond; 1 = ε_c is the
    classifier-corrected ε̂ computed upstream).  The scalar-prefetch
    table is (5, Bs) — ``(mode, ᾱ_t, ᾱ_prev, s, active)`` per row — and
    the same row-window contract applies: tensor row b reads scalar slot
    ``row_offset + b``, with the bounds check only for concrete offsets."""
    if interpret is None:
        interpret = _on_cpu()
    shape = x.shape
    B = shape[0]
    n = int(np.prod(shape[1:]))
    rows = -(-n // K.LANES)
    rows = -(-rows // 8) * 8
    pad = rows * K.LANES - n

    def flat(a):
        a = a.reshape(B, -1)
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad)))
        return a.reshape(B, rows, K.LANES)

    scal = jnp.stack([
        jnp.asarray(mode, jnp.float32).reshape(-1),
        jnp.asarray(ab_t, jnp.float32).reshape(-1),
        jnp.asarray(ab_prev, jnp.float32).reshape(-1),
        jnp.asarray(s, jnp.float32).reshape(-1),
        jnp.asarray(active).astype(jnp.float32).reshape(-1),
    ])
    if isinstance(row_offset, (int, np.integer)) and \
            (row_offset < 0 or scal.shape[1] < row_offset + B):
        raise ValueError(
            f"mixed scalars span {scal.shape[1]} rows; window "
            f"[{row_offset}, {row_offset + B}) is out of range")
    off = jnp.asarray(row_offset, jnp.int32).reshape(1)
    out = K.cfg_update_mixed_3d(flat(x), flat(eps_c), flat(eps_u),
                                flat(noise), off, scal, eta=float(eta),
                                interpret=interpret)
    return out.reshape(B, -1)[:, :n].reshape(shape)
