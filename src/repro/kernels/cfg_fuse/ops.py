"""jit'd public wrapper around the cfg_fuse Pallas kernel: handles
flattening/padding to the (rows, 128) lane layout and CPU interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cfg_fuse import kernel as K


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def cfg_update(x, eps_c, eps_u, s, ab_t, ab_prev, noise, eta: float = 1.0,
               *, interpret: bool | None = None):
    """Fused (1+s)·ε_c − s·ε_u guidance + ancestral update.  Shapes of
    x/eps_c/eps_u/noise are identical and arbitrary; s and eta are static."""
    if interpret is None:
        interpret = _on_cpu()
    shape = x.shape
    n = int(np.prod(shape))
    rows = -(-n // K.LANES)
    rows = -(-rows // 8) * 8
    pad = rows * K.LANES - n

    def flat(a):
        a = a.reshape(-1)
        if pad:
            a = jnp.pad(a, (0, pad))
        return a.reshape(rows, K.LANES)

    out = K.cfg_update_2d(flat(x), flat(eps_c), flat(eps_u), flat(noise),
                          jnp.asarray(ab_t, jnp.float32),
                          jnp.asarray(ab_prev, jnp.float32),
                          s=float(s), eta=float(eta), interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)


def cfg_update_rowwise(x, eps_c, eps_u, s, ab_t, ab_prev, noise, active,
                       eta: float = 1.0, *, interpret: bool | None = None):
    """Per-row fused update for ragged waves: ``s``/``ab_t``/``ab_prev``/
    ``active`` are (B,) vectors — every batch row carries its own guidance
    scale and schedule position, and ``active`` freezes rows whose right-
    aligned trajectory has not started yet.  Each image is flattened to
    its own (rows, 128) lane block so the kernel's per-row scalars apply
    exactly to that image's elements."""
    if interpret is None:
        interpret = _on_cpu()
    shape = x.shape
    B = shape[0]
    n = int(np.prod(shape[1:]))
    rows = -(-n // K.LANES)
    rows = -(-rows // 8) * 8
    pad = rows * K.LANES - n

    def flat(a):
        a = a.reshape(B, -1)
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad)))
        return a.reshape(B, rows, K.LANES)

    scal = jnp.stack([
        jnp.asarray(ab_t, jnp.float32).reshape(B),
        jnp.asarray(ab_prev, jnp.float32).reshape(B),
        jnp.asarray(s, jnp.float32).reshape(B),
        jnp.asarray(active).astype(jnp.float32).reshape(B),
    ])
    out = K.cfg_update_rowwise_3d(flat(x), flat(eps_c), flat(eps_u),
                                  flat(noise), scal, eta=float(eta),
                                  interpret=interpret)
    return out.reshape(B, -1)[:, :n].reshape(shape)
