"""Pure-jnp oracle for the fused CFG-guidance + ancestral-update step.

This is the numerical contract the Pallas kernel must match:

    ε̂      = (1+s)·ε_c − s·ε_u                        (paper Eq. 8)
    x̂₀     = clip((x_t − √(1−ᾱ_t)·ε̂)/√ᾱ_t, ±1)
    σ_t    = η·√((1−ᾱ_prev)/(1−ᾱ_t)·(1−ᾱ_t/ᾱ_prev))
    x_{t-1} = √ᾱ_prev·x̂₀ + √(1−ᾱ_prev−σ²)·ε̂ + σ·z     (paper Eq. 9 / DDIM η)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ancestral_step(x, eps, ab_t, ab_prev, noise, eta: float = 1.0):
    sqrt_ab = jnp.sqrt(ab_t)
    sqrt_1mab = jnp.sqrt(1.0 - ab_t)
    x0 = (x - sqrt_1mab * eps) / sqrt_ab
    x0 = jnp.clip(x0, -1.0, 1.0)
    var = (1.0 - ab_prev) / (1.0 - ab_t) * (1.0 - ab_t / ab_prev)
    sigma = eta * jnp.sqrt(jnp.maximum(var, 0.0))
    dir_coef = jnp.sqrt(jnp.maximum(1.0 - ab_prev - sigma ** 2, 0.0))
    return jnp.sqrt(ab_prev) * x0 + dir_coef * eps + sigma * noise


def cfg_update(x, eps_c, eps_u, s, ab_t, ab_prev, noise, eta: float = 1.0):
    eps = (1.0 + s) * eps_c - s * eps_u
    return ancestral_step(x, eps, ab_t, ab_prev, noise, eta)


def cfg_update_rowwise(x, eps_c, eps_u, s, ab_t, ab_prev, noise, active,
                       eta: float = 1.0):
    """Per-row (ragged-wave) variant: ``s``/``ab_t``/``ab_prev`` are (B,)
    vectors — one (guidance, schedule-position) per batch row — and
    ``active`` (B,) freezes rows whose trajectory has not started (right-
    aligned ragged respacing): a frozen row passes through bit-unchanged.
    With every row agreeing this is elementwise-identical arithmetic to
    ``cfg_update``, so the two are bit-exact on the shared rows."""
    r = lambda v: jnp.asarray(v).reshape((-1,) + (1,) * (x.ndim - 1))
    s, ab_t, ab_prev = r(s), r(ab_t), r(ab_prev)
    eps = (1.0 + s) * eps_c - s * eps_u
    x0 = (x - jnp.sqrt(1.0 - ab_t) * eps) / jnp.sqrt(ab_t)
    x0 = jnp.clip(x0, -1.0, 1.0)
    var = (1.0 - ab_prev) / (1.0 - ab_t) * (1.0 - ab_t / ab_prev)
    sigma = eta * jnp.sqrt(jnp.maximum(var, 0.0))
    dir_coef = jnp.sqrt(jnp.maximum(1.0 - ab_prev - sigma ** 2, 0.0))
    out = jnp.sqrt(ab_prev) * x0 + dir_coef * eps + sigma * noise
    return jnp.where(r(active), out, x)


def cfg_update_mixed(x, eps_c, eps_u, mode, s, ab_t, ab_prev, noise, active,
                     eta: float = 1.0):
    """Per-row MIXED-guidance variant: ``mode`` (B,) selects the guidance
    combine per row — 0 is classifier-free ``(1+s)·ε_c − s·ε_u`` (with
    uncond as its s=0, null-cond degenerate point), 1 takes ``eps_c`` as
    the already-corrected ε̂ (classifier guidance applies its gradient
    term upstream, where the classifier ensemble lives).  Every other
    line is the ``cfg_update_rowwise`` arithmetic, so an all-mode-0 call
    is bit-identical to the pure-cfg rowwise update."""
    r = lambda v: jnp.asarray(v).reshape((-1,) + (1,) * (x.ndim - 1))
    mode, s, ab_t, ab_prev = r(mode), r(s), r(ab_t), r(ab_prev)
    eps = jnp.where(mode < 0.5, (1.0 + s) * eps_c - s * eps_u, eps_c)
    x0 = (x - jnp.sqrt(1.0 - ab_t) * eps) / jnp.sqrt(ab_t)
    x0 = jnp.clip(x0, -1.0, 1.0)
    var = (1.0 - ab_prev) / (1.0 - ab_t) * (1.0 - ab_t / ab_prev)
    sigma = eta * jnp.sqrt(jnp.maximum(var, 0.0))
    dir_coef = jnp.sqrt(jnp.maximum(1.0 - ab_prev - sigma ** 2, 0.0))
    out = jnp.sqrt(ab_prev) * x0 + dir_coef * eps + sigma * noise
    return jnp.where(r(active), out, x)


def cfg_update_mixed_windowed(x, eps_c, eps_u, mode, s, ab_t, ab_prev, noise,
                              active, row_offset=0, eta: float = 1.0):
    """Segment-offset oracle for the mixed update: the per-row scalar
    vectors (including ``mode``) span the wave's FULL row range, tensor
    row b reads slot ``row_offset + b``.  ``row_offset`` may be traced."""
    B = x.shape[0]
    sl = lambda v: jax.lax.dynamic_slice_in_dim(jnp.asarray(v),
                                                row_offset, B, 0)
    return cfg_update_mixed(x, eps_c, eps_u, sl(mode), sl(s), sl(ab_t),
                            sl(ab_prev), noise, sl(active), eta)


def cfg_update_rowwise_windowed(x, eps_c, eps_u, s, ab_t, ab_prev, noise,
                                active, row_offset=0, eta: float = 1.0):
    """Oracle for the segment-offset kernel path: the scalar vectors span
    a wave's FULL row range and ``x`` holds only the window starting at
    ``row_offset`` (a compaction segment's live rows) — tensor row b must
    read scalar slot ``row_offset + b``.  Defined as the plain rowwise
    update on the sliced window, which is exactly what the kernel's
    offset indexing must reproduce.  ``row_offset`` may be a TRACED
    scalar (``dynamic_slice``, values identical to a static slice), so
    one compiled window executable serves every host offset."""
    B = x.shape[0]
    sl = lambda v: jax.lax.dynamic_slice_in_dim(jnp.asarray(v),
                                                row_offset, B, 0)
    return cfg_update_rowwise(x, eps_c, eps_u, sl(s), sl(ab_t), sl(ab_prev),
                              noise, sl(active), eta)
