"""Public wrapper for the fused adaLN LayerNorm kernel: token-dim padding
to the sublane multiple, CPU interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.adaln_norm import kernel as K


def adaln_norm(x, scale, shift, eps: float = 1e-6, *,
               interpret: bool | None = None):
    """x: (B, N, d) tokens; scale/shift: (B, d) per-batch-row modulation."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, N, d = x.shape
    pad = (-N) % 8
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    out = K.adaln_norm_3d(x, scale, shift, eps=eps, interpret=interpret)
    if pad:
        out = out[:, :N]
    return out
