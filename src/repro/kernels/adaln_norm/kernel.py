"""Pallas TPU fused adaLN LayerNorm: one VMEM pass computing the DiT's
mean-subtracting LayerNorm plus the adaLN-zero modulation
``(1 + scale)·x̂ + shift`` — replacing the naive mean/var/normalise/
mul/add HBM round-trips at each of the three DiT modulation sites.

Tiling: grid (B, token blocks); each program holds a (block_n, d) slab of
one batch row's tokens with that row's (d,) scale/shift resident — d stays
whole so the row reduction is VMEM-local.  Sibling of ``kernels/rmsnorm``
with per-batch-row modulation operands instead of one shared gain."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_TOKENS = 256


def _adaln_kernel(x_ref, s_ref, b_ref, o_ref, *, eps):
    x = x_ref[0].astype(jnp.float32)                    # (block_n, d)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    y = xc * jax.lax.rsqrt(var + eps)
    s = s_ref[0].astype(jnp.float32)                    # (d,)
    b = b_ref[0].astype(jnp.float32)
    o_ref[0] = (y * (1.0 + s)[None] + b[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def adaln_norm_3d(x, scale, shift, *, eps: float = 1e-6,
                  interpret: bool = False):
    """x: (B, N, d); scale/shift: (B, d)."""
    B, N, d = x.shape
    block = min(BLOCK_TOKENS, N)
    return pl.pallas_call(
        functools.partial(_adaln_kernel, eps=eps),
        grid=(B, pl.cdiv(N, block)),
        in_specs=[pl.BlockSpec((1, block, d), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((1, d), lambda b, i: (b, 0)),
                  pl.BlockSpec((1, d), lambda b, i: (b, 0))],
        out_specs=pl.BlockSpec((1, block, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale, shift)
