"""Pure-jnp oracle for the fused adaLN LayerNorm kernel.

Matches the DiT modulation sites in ``repro.diffusion.dit``: a
mean-subtracting LayerNorm (no learned gain/bias) followed by the
adaLN-zero modulation ``(1 + scale)·x̂ + shift`` with a per-batch-row
(d,)-vector scale/shift (``(1+scale)`` convention, like
``kernels.rmsnorm``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adaln_norm(x, scale, shift, eps: float = 1e-6):
    """x: (B, N, d) tokens; scale/shift: (B, d) per-row modulation."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    xc = xf - mu
    var = jnp.mean(jnp.square(xc), axis=-1, keepdims=True)
    y = xc * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale.astype(jnp.float32))[:, None] \
        + shift.astype(jnp.float32)[:, None]
    return y.astype(dt)
