from repro.optim.optimizers import (adamw, apply_updates, clip_by_global_norm,
                                    cosine_schedule, init_adamw, init_sgdm,
                                    sgdm)
