"""Optimizers (pure-JAX, no optax): AdamW, SGD+momentum, schedules.

Optimizer state mirrors the parameter pytree, so the sharding rules that
partition a parameter partition its moments identically (ZeRO-style).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: any
    nu: any


class SGDMState(NamedTuple):
    step: jax.Array
    momentum: any


def init_adamw(params) -> AdamWState:
    z = lambda: jax.tree.map(jnp.zeros_like, params)
    return AdamWState(jnp.zeros((), jnp.int32), z(), z())


def adamw(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
          eps=1e-8, weight_decay=0.0):
    """Returns (updates, new_state).  ``lr`` may be a scalar or callable."""
    step = state.step + 1
    if callable(lr):
        lr = lr(step)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    def upd(m, v, p):
        mhat = m / bc1
        vhat = v / bc2
        return -lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
    updates = jax.tree.map(upd, mu, nu, params)
    return updates, AdamWState(step, mu, nu)


def init_sgdm(params) -> SGDMState:
    return SGDMState(jnp.zeros((), jnp.int32),
                     jax.tree.map(jnp.zeros_like, params))


def sgdm(grads, state: SGDMState, params, *, lr, momentum=0.9,
         weight_decay=0.0):
    step = state.step + 1
    if callable(lr):
        lr = lr(step)
    mom = jax.tree.map(lambda m, g, p: momentum * m + g + weight_decay * p,
                       state.momentum, grads, params)
    updates = jax.tree.map(lambda m: -lr * m, mom)
    return updates, SGDMState(step, mom)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr
