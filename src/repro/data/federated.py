"""Procedural multi-domain image data with the paper's non-IID structure.

Stands in for NICO++/DomainNet/OpenImage (DESIGN.md §8): every image has a
*category* (foreground shape — the label) and a *domain* (background
palette + texture statistics).  The paper's **feature-distribution skew**
is reproduced exactly: each client owns a single domain of every category
(NICO++/DomainNet division, §V-b), 6 clients = 6 domains.

Images are deterministic functions of (seed, category, domain, instance):
category fixes a low-frequency foreground mask; domain fixes background
colour/texture; instances jitter phase/position/noise.  A model must use
the category shape (not the domain palette) to generalise across clients —
the same pressure the real benchmarks apply.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.oscar import DataConfig


@dataclass
class FederatedData:
    # per-client training shards (feature-skew: client r == domain r)
    client_images: np.ndarray   # (R, n_client, H, W, C) in [-1, 1]
    client_labels: np.ndarray   # (R, n_client)
    client_domains: np.ndarray  # (R, n_client)
    # global test set (all domains mixed)
    test_images: np.ndarray
    test_labels: np.ndarray
    test_domains: np.ndarray
    num_categories: int
    num_domains: int
    # optional DM pre-training pool (disjoint instances; the "web data"
    # a pre-trained diffusion model was built from)
    pool_images: np.ndarray | None = None
    pool_labels: np.ndarray | None = None
    pool_domains: np.ndarray | None = None

    def client_test_set(self, r: int):
        """Domain-r test slice = the paper's 'client-r test set'."""
        m = self.test_domains == r
        return self.test_images[m], self.test_labels[m]


def _category_mask(rng: np.random.Generator, size: int) -> np.ndarray:
    """Low-frequency random foreground mask in [0,1]."""
    g = rng.normal(size=(4, 4))
    k = size // 4
    up = np.kron(g, np.ones((k, k)))
    # smooth with a small box filter
    pad = np.pad(up, 2, mode="wrap")
    sm = sum(pad[i:i + size, j:j + size] for i in range(5) for j in range(5)) / 25.0
    mask = (sm > np.quantile(sm, 0.6)).astype(np.float32)
    return mask


def _domain_style(rng: np.random.Generator):
    bg = rng.uniform(-0.9, 0.9, size=(3,))
    freq = rng.integers(1, 4)
    axis = rng.integers(0, 2)
    amp = rng.uniform(0.1, 0.35)
    tint = rng.uniform(-0.3, 0.3, size=(3,))
    return bg, int(freq), int(axis), amp, tint


def _render(cat_mask, style, fg_color, rng, size, distractor=None):
    """One image.  Deliberately hard: large positional jitter, flips,
    brightness/contrast jitter, a low-alpha distractor shape from another
    category, and strong pixel noise — so 30 images/category locally
    overfits (the paper's Local row is weak) and cross-domain transfer
    requires real shape recognition."""
    bg, freq, axis, amp, tint = style
    yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
    phase = rng.uniform(0, 2 * np.pi)
    wave = np.sin(2 * np.pi * freq * (yy if axis == 0 else xx) / size + phase)
    dy, dx = rng.integers(-4, 5, size=2)
    m = np.roll(np.roll(cat_mask, dy, 0), dx, 1)
    if rng.random() < 0.5:
        m = m[:, ::-1]
    m = m[..., None]
    fg = np.clip(fg_color + tint + rng.normal(scale=0.15, size=3), -1, 1)
    img = (1 - m) * (bg + amp * wave[..., None]) + m * fg
    if distractor is not None:
        ddy, ddx = rng.integers(-4, 5, size=2)
        dmask = np.roll(np.roll(distractor, ddy, 0), ddx, 1)[..., None]
        img = img * (1 - 0.35 * dmask) + 0.35 * dmask * rng.uniform(-1, 1, size=3)
    # brightness / contrast jitter
    img = img * rng.uniform(0.8, 1.2) + rng.uniform(-0.15, 0.15)
    img += rng.normal(scale=0.15, size=img.shape)
    return np.clip(img, -1.0, 1.0).astype(np.float32)


def make_federated_data(dc: DataConfig) -> FederatedData:
    rng = np.random.default_rng(dc.seed)
    C, D, size = dc.num_categories, dc.num_domains, dc.image_size
    cat_masks = [_category_mask(rng, size) for _ in range(C)]
    cat_colors = [rng.uniform(-1, 1, size=(3,)) for _ in range(C)]
    styles = [_domain_style(rng) for _ in range(D)]

    def block(n_per):
        imgs, labels, doms = [], [], []
        for d in range(D):
            for c in range(C):
                for _ in range(n_per):
                    dist = None
                    if rng.random() < 0.5:
                        dist = cat_masks[int(rng.integers(0, C))]
                    imgs.append(_render(cat_masks[c], styles[d],
                                        cat_colors[c], rng, size,
                                        distractor=dist))
                    labels.append(c)
                    doms.append(d)
        return (np.stack(imgs), np.array(labels, np.int32),
                np.array(doms, np.int32))

    tr_i, tr_l, tr_d = block(dc.train_per_cat_dom)
    te_i, te_l, te_d = block(dc.test_per_cat_dom)
    pool = (None, None, None)
    if dc.pretrain_pool_per_cat_dom:
        pool = block(dc.pretrain_pool_per_cat_dom)

    ci, cl, cd = partition_feature_skew(tr_i, tr_l, tr_d, D)
    return FederatedData(ci, cl, cd, te_i, te_l, te_d, C, D, *pool)


def partition_feature_skew(images, labels, domains, num_clients: int):
    """Paper §V-b: client r owns domain r for every category."""
    ci, cl, cd = [], [], []
    for r in range(num_clients):
        m = domains == r
        ci.append(images[m])
        cl.append(labels[m])
        cd.append(domains[m])
    n = min(len(x) for x in ci)
    return (np.stack([x[:n] for x in ci]), np.stack([x[:n] for x in cl]),
            np.stack([x[:n] for x in cd]))


def partition_label_skew(images, labels, num_clients: int, alpha: float = 0.5,
                         seed: int = 0):
    """Dirichlet label-skew partition (standard FL benchmark alternative)."""
    rng = np.random.default_rng(seed)
    C = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(C)]
    client_idx = [[] for _ in range(num_clients)]
    for c in range(C):
        rng.shuffle(idx_by_class[c])
        props = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(props) * len(idx_by_class[c])).astype(int)[:-1]
        for r, part in enumerate(np.split(idx_by_class[c], cuts)):
            client_idx[r].extend(part.tolist())
    return [np.array(sorted(ix), np.int64) for ix in client_idx]
