from repro.data.federated import (FederatedData, make_federated_data,
                                  partition_feature_skew, partition_label_skew)
