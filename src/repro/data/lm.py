"""LM data pipeline substrate: synthetic corpora, packing, deterministic
batching — the token-side input path for the assigned-architecture zoo
(train_lm example and the production launcher consume this).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


def markov_corpus(vocab: int, n_tokens: int, seed: int = 0,
                  alpha: float = 0.3) -> np.ndarray:
    """Synthetic corpus with learnable bigram structure (a dense Dirichlet
    transition matrix) — perplexity decreases under real training."""
    rng = np.random.default_rng(seed)
    # sparse-ish rows: zipfian support keeps the matrix memory-sane
    support = min(vocab, 64)
    probs = rng.dirichlet([alpha] * support, size=vocab)
    cols = np.stack([rng.choice(vocab, size=support, replace=False)
                     for _ in range(min(vocab, 4096))])
    if vocab > 4096:   # share column patterns above 4k states
        cols = cols[rng.integers(0, 4096, size=vocab)]
    out = np.empty(n_tokens, np.int32)
    s = int(rng.integers(0, vocab))
    for i in range(n_tokens):
        out[i] = s
        s = int(cols[s][rng.choice(support, p=probs[s])])
    return out


def copy_task_corpus(vocab: int, n_tokens: int, span: int = 8,
                     seed: int = 0) -> np.ndarray:
    """Repeat-after-me structure: spans are emitted twice — induction-head
    fodder; any architecture with working memory should exploit it."""
    rng = np.random.default_rng(seed)
    out = []
    while sum(len(c) for c in out) < n_tokens:
        s = rng.integers(0, vocab, size=span)
        out.append(np.concatenate([s, s]))
    return np.concatenate(out)[:n_tokens].astype(np.int32)


def pack_sequences(tokens: np.ndarray, seq_len: int) -> np.ndarray:
    """Pack a flat token stream into (N, seq_len) rows (drop remainder)."""
    n = len(tokens) // seq_len
    return tokens[:n * seq_len].reshape(n, seq_len)


@dataclass
class LMDataset:
    rows: np.ndarray          # (N, seq_len) int32
    vocab: int

    def batches(self, batch: int, *, seed: int = 0,
                epochs: int | None = None) -> Iterator[dict]:
        """Deterministic shuffled batches: {'tokens': (B, S)}."""
        rng = np.random.default_rng(seed)
        N = len(self.rows)
        epoch = 0
        while epochs is None or epoch < epochs:
            order = rng.permutation(N)
            for i in range(0, N - batch + 1, batch):
                yield {"tokens": self.rows[order[i:i + batch]]}
            epoch += 1


def make_lm_dataset(vocab: int, *, seq_len: int = 128, n_tokens: int = 200_000,
                    kind: str = "markov", seed: int = 0) -> LMDataset:
    gen = markov_corpus if kind == "markov" else copy_task_corpus
    return LMDataset(pack_sequences(gen(vocab, n_tokens, seed=seed), seq_len),
                     vocab)
