"""Shared small utilities: pytree helpers, initializers, rng plumbing."""
from __future__ import annotations

import math
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# Initializers (functional; every init takes an explicit key).
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype=jnp.float32, stddev=0.02):
    return (jax.random.normal(key, shape) * stddev).astype(dtype)


def lecun_init(key, shape, dtype=jnp.float32, fan_in_axes=(0,)):
    fan_in = int(np.prod([shape[a] for a in fan_in_axes]))
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def zeros_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def split_keys(key, names: Iterable[str]) -> dict[str, jax.Array]:
    names = list(names)
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------

def tree_size(tree: PyTree) -> int:
    """Total number of scalar parameters in a pytree (works on SDS too)."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_paths(tree: PyTree) -> list[tuple[str, Any]]:
    """Flatten to ('a/b/c', leaf) pairs using dict keys as path parts."""
    out = []
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        leaves.append(fn("/".join(parts), leaf))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_init(init_fn: Callable[..., PyTree], *args) -> PyTree:
    """Shape-only init: returns a pytree of ShapeDtypeStruct, no allocation."""
    return jax.eval_shape(init_fn, *args)


def cast_tree(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
