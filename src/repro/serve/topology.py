"""Host topology + wave placement: the multi-host serving substrate.

OSCAR's one-round protocol makes the SERVER the scaling bottleneck — one
burst of D_syn generation for every client — so a drain must be able to
run across the H hosts of a production pod instead of one monolithic
process.  This module is the placement layer the engine schedules
against:

* ``HostTopology`` describes the serving fleet: how many hosts, each
  host's device count (its share of a wave is proportional), and each
  host's ROW GRANULE (windows are rounded up so a host's rows divide its
  data-parallel device count).  Built from a mesh
  (``launch/mesh.py::make_serving_mesh``, or any (data, model) mesh whose
  data axis is partitioned into H contiguous host groups — the same
  trick ``make_host_mesh`` uses) or ``simulated`` without devices, which
  is how CI exercises H ∈ {1, 2, 4} in one process.

* ``WavePlacement`` maps the rows each host packed into CONTIGUOUS
  PER-HOST WINDOWS of one merged wave: window ``w`` covers wave rows
  ``[w.offset, w.offset + w.rows)``, padding is per-window (a host never
  pads for another host's tail), and ``w.offset`` is exactly the
  ``row_offset`` the segment-offset ``cfg_fuse`` path uses to read the
  window's per-row (ᾱ_t, ᾱ_prev, s, active) scalars out of the wave-
  resident table — no per-host sliced copies of the table.

The load-bearing invariant lives one layer down (``serve/synthesis.py``):
row noise is keyed by REQUEST IDENTITY, so D_syn is bit-identical
regardless of host count, placement, or arrival order — topology only
moves rows between hosts, never changes their values.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import numpy as np

from repro.serve.faults import AllHostsLostError


@dataclass(frozen=True)
class HostWindow:
    """One host's contiguous slice of a placed wave."""
    host: int
    offset: int            # first wave row (== the kernel row_offset)
    rows: int              # padded window size (host-granule multiple)
    real: int              # rows actually packed (rows - real is padding)

    def __post_init__(self):
        if not (0 < self.real <= self.rows):
            raise ValueError(f"window real={self.real} rows={self.rows}: "
                             f"need 0 < real <= rows")
        if self.offset < 0 or self.host < 0:
            raise ValueError(f"window host={self.host} offset={self.offset} "
                             f"must be non-negative")

    @property
    def span_attrs(self) -> dict:
        """Attributes a trace span carries for this window — ``host``
        routes the span onto the host's timeline track."""
        return {"host": self.host, "offset": self.offset,
                "rows": self.rows, "real": self.real}


@dataclass(frozen=True)
class HostTopology:
    """The serving fleet a drain is placed over.

    ``device_counts[h]`` weights host h's share of every wave;
    ``granules[h]`` is the row multiple its windows are rounded to (its
    data-parallel device count on a real mesh, the engine granule when
    simulated).  ``mesh`` (optional, identity-irrelevant) is the mesh the
    topology was derived from — ``launch/mesh.py::host_submesh`` carves
    out host h's compute mesh from it.
    """
    device_counts: tuple
    granules: tuple
    mesh: Any = field(default=None, compare=False, repr=False)
    failed: frozenset = frozenset()

    def __post_init__(self):
        if len(self.device_counts) < 1:
            raise ValueError("HostTopology: need at least one host")
        if len(self.granules) != len(self.device_counts):
            raise ValueError(
                f"HostTopology: {len(self.device_counts)} device counts vs "
                f"{len(self.granules)} granules")
        if any(d < 1 for d in self.device_counts) or \
                any(g < 1 for g in self.granules):
            raise ValueError("HostTopology: device counts and granules "
                             "must be >= 1")
        object.__setattr__(self, "failed", frozenset(self.failed))
        if any(not 0 <= h < len(self.device_counts) for h in self.failed):
            raise ValueError(f"failed hosts {sorted(self.failed)} out of "
                             f"range for {len(self.device_counts)} hosts")
        if len(self.failed) >= len(self.device_counts):
            raise AllHostsLostError(
                f"all {len(self.device_counts)} hosts failed")

    @property
    def num_hosts(self) -> int:
        return len(self.device_counts)

    @property
    def live_hosts(self) -> tuple:
        """Hosts still serving, in host order."""
        return tuple(h for h in range(self.num_hosts)
                     if h not in self.failed)

    def mark_failed(self, host: int) -> "HostTopology":
        """Elastic membership: the topology with ``host`` removed from
        service.  Dead hosts keep their index (per-host stats stay
        aligned) but get zero wave quota and no ingress traffic; raises
        ``AllHostsLostError`` when no survivor would remain.  Marking an
        already-dead host is a no-op."""
        if not 0 <= host < self.num_hosts:
            raise ValueError(f"host {host} out of range for "
                             f"{self.num_hosts} hosts")
        if host in self.failed:
            return self
        return replace(self, failed=self.failed | {host})

    @classmethod
    def simulated(cls, hosts: int, *, granule: int = 1) -> "HostTopology":
        """Device-less topology: H equal-weight hosts in one process —
        per-host ingress queues, per-host windows, per-host stats, but
        every window sampled locally.  This is what CI runs; outputs are
        bit-identical to any real placement because row noise is keyed by
        request identity."""
        if not isinstance(hosts, int) or isinstance(hosts, bool) or hosts < 1:
            raise ValueError(f"simulated topology: hosts={hosts!r} must be "
                             f"an int >= 1")
        return cls(device_counts=(1,) * hosts, granules=(granule,) * hosts)

    @classmethod
    def from_mesh(cls, mesh, hosts: int | None = None) -> "HostTopology":
        """Derive the topology from a mesh.

        A serving mesh (explicit ``hosts`` axis — ``make_serving_mesh``)
        declares its own host count and per-host (data, model) submesh
        shape.  Any other mesh is partitioned into ``hosts`` contiguous
        groups along its data axes, so ``hosts`` must divide the data-
        parallel device count.
        """
        from repro.launch.mesh import mesh_axes
        if "hosts" in mesh.axis_names:
            declared = int(mesh.shape["hosts"])
            if hosts is not None and hosts != declared:
                raise ValueError(
                    f"mesh declares hosts={declared}; got hosts={hosts}")
            hosts = declared
        if hosts is None:
            raise ValueError("from_mesh: pass hosts=H for a mesh without a "
                             "'hosts' axis")
        if not isinstance(hosts, int) or isinstance(hosts, bool) or hosts < 1:
            raise ValueError(f"from_mesh: hosts={hosts!r} must be an "
                             f"int >= 1")
        ax = mesh_axes(mesh)
        dsize = int(np.prod([mesh.shape[n] for n in ax.data])) if ax.data \
            else 1
        msize = int(mesh.shape.get("model", 1))
        if "hosts" not in mesh.axis_names:
            lead = int(mesh.shape[ax.data[0]]) if ax.data else 1
            if lead % hosts:
                raise ValueError(
                    f"cannot place {hosts} hosts on a mesh with a "
                    f"{lead}-wide leading data axis ({dict(mesh.shape)}): "
                    f"hosts must divide it (each host takes a contiguous "
                    f"block) — use make_serving_mesh(hosts={hosts}, ...) "
                    f"or pick hosts in "
                    f"{[h for h in range(1, lead + 1) if lead % h == 0]}")
            dsize //= hosts
        return cls(device_counts=(dsize * msize,) * hosts,
                   granules=(dsize,) * hosts, mesh=mesh)

    def assign(self, rid: int) -> int:
        """Ingress routing: which host's queue a request lands on.  Keyed
        by the request's identity (rid), NOT arrival order, so replaying
        a trace in any order routes every request identically.  Only live
        hosts take traffic; routing is identity-keyed within the
        survivor set (the ROWS a rerouted request produces are unchanged
        — row noise is identity-keyed, not host-keyed)."""
        live = self.live_hosts
        return live[rid % len(live)]

    def host_mesh(self, host: int):
        """Host ``host``'s compute mesh, or None for a simulated
        topology.  A serving mesh slices its ``hosts`` axis away
        (``launch/mesh.py::host_submesh``); a plain (data, model) mesh is
        partitioned into contiguous blocks along its leading data axis —
        the same trick ``make_host_mesh`` plays with the local devices."""
        if self.mesh is None:
            return None
        if not 0 <= host < self.num_hosts:
            raise ValueError(f"host {host} out of range for "
                             f"{self.num_hosts} hosts")
        if "hosts" in self.mesh.axis_names:
            from repro.launch.mesh import host_submesh
            return host_submesh(self.mesh, host)
        from jax.sharding import Mesh
        from repro.launch.mesh import mesh_axes
        lead = mesh_axes(self.mesh).data[0]
        axis = self.mesh.axis_names.index(lead)
        per = int(self.mesh.shape[lead]) // self.num_hosts
        idx = [slice(None)] * self.mesh.devices.ndim
        idx[axis] = slice(host * per, (host + 1) * per)
        return Mesh(self.mesh.devices[tuple(idx)], self.mesh.axis_names)

    def wave_quotas(self, wave_size: int) -> tuple:
        """Per-host row targets for one wave: ``wave_size`` split
        proportional to LIVE device counts, each rounded up to the
        host's granule (never below one granule — a live host always
        gets a packable window).  Dead hosts get quota 0, so the wave
        re-spreads over survivors through the same proportional split —
        failover IS a re-quota, nothing more."""
        total = sum(d for h, d in enumerate(self.device_counts)
                    if h not in self.failed)
        quotas = []
        for h, (d, g) in enumerate(zip(self.device_counts, self.granules)):
            if h in self.failed:
                quotas.append(0)
                continue
            share = -(-wave_size * d // total)          # ceil split
            quotas.append(max(-(-share // g) * g, g))
        return tuple(quotas)


@dataclass(frozen=True)
class WavePlacement:
    """Contiguous per-host windows of one merged wave.  Window order is
    host order; concatenating the windows IS the wave, and each window's
    ``offset`` doubles as the kernel ``row_offset`` into the wave-resident
    scalar table."""
    windows: tuple

    def __post_init__(self):
        off = 0
        for w in self.windows:
            if w.offset != off:
                raise ValueError(
                    f"placement windows must tile the wave contiguously: "
                    f"host {w.host} starts at {w.offset}, expected {off}")
            off += w.rows

    @classmethod
    def plan(cls, host_rows, granules, pad_to=None) -> "WavePlacement":
        """Place the rows each host packed: host h's window holds its own
        ``host_rows[h]`` rows padded up to ``granules[h]``; hosts with no
        rows contribute no window (and no padding).  ``pad_to`` (optional,
        per-host row counts) pads each NON-EMPTY window further, up to
        ``pad_to[h]`` — the drain uses it to give a tail wave the same
        window geometry as the full waves before it, so the tail reuses
        their compiled executables instead of compiling its own (padding
        rows duplicate a real row and are discarded at scatter, so the
        promotion is invisible in D_syn)."""
        if len(host_rows) != len(granules):
            raise ValueError(f"{len(host_rows)} hosts vs "
                             f"{len(granules)} granules")
        windows, off = [], 0
        for h, (n, g) in enumerate(zip(host_rows, granules)):
            if n == 0:
                continue
            rows = -(-n // g) * g
            if pad_to is not None:
                rows = max(rows, pad_to[h])
            windows.append(HostWindow(host=h, offset=off, rows=rows, real=n))
            off += rows
        return cls(windows=tuple(windows))

    @property
    def total_rows(self) -> int:
        return sum(w.rows for w in self.windows)

    @property
    def real_rows(self) -> int:
        return sum(w.real for w in self.windows)

    @property
    def padded(self) -> int:
        return self.total_rows - self.real_rows
