"""SynthesisService — the streaming front door to the SynthesisEngine.

Where ``SynthesisEngine`` is the wave scheduler (pack → sample → scatter),
the service is the request-lifecycle layer the OSCAR server and the
DM-assisted baselines actually talk to:

* ``submit*`` returns a ``SynthesisFuture`` immediately; ``result()``
  drains on demand, so callers no longer choreograph submit/run phases;
* drains are STREAMING: a ``poll`` callback (or another thread calling
  ``submit`` mid-drain) feeds late-arriving requests into the engine's
  live group queues, where they fill partially-empty open waves instead
  of padding — see ``SynthesisEngine.run``.  Thread submissions are
  folded in at each wave boundary while waves remain in flight; only a
  ``poll`` can keep a drain alive waiting for arrivals;
* a persistent ``SynthesisStore`` can be attached so the
  (encoding-hash, guidance, steps) cache survives the process: a cold
  process against a warm store answers the whole workload with zero
  sampler calls and bit-identical D_syn;
* drain keys are a deterministic stream: drain ``i`` uses
  ``fold_in(base_key, i)``, so a service constructed with the same seed
  and fed the same arrival trace reproduces its outputs exactly.

Thread-safety: ``submit`` may be called from any thread (including while
a drain is running — that is the streaming path); ``drain`` itself is
serialized on an internal lock.  A ``poll`` callback runs on the
draining thread and may submit freely.
"""
from __future__ import annotations

import threading
import weakref
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.obs.trace import Tracer
from repro.serve.faults import (FaultInjector, RetryPolicy,
                                SynthesisError, UnservedRequestError)
from repro.serve.store import SynthesisStore
from repro.serve.synthesis import SynthesisEngine


class SynthesisFuture:
    """Handle for one submitted request.  ``result()`` drains the queue
    if needed.  Rows are delivered straight onto the future (the service
    only holds a weak reference), so a long-lived service accumulates
    nothing: discard the future and its images are collectable.

    A future resolves to rows OR to a typed ``SynthesisError``
    (``serve/faults.py``) — never silently to nothing: ``result()``
    raises the stored error, ``exception()`` returns it, and a drain
    that somehow bypassed delivery raises ``UnservedRequestError``."""

    def __init__(self, service: "SynthesisService", rid: int):
        self._service = service
        self._value: Optional[np.ndarray] = None
        self._error: Optional[SynthesisError] = None
        self.rid = rid

    def done(self) -> bool:
        return self._value is not None or self._error is not None

    def result(self) -> np.ndarray:
        if not self.done():
            self._service.drain()
        if self._error is not None:
            raise self._error
        if self._value is None:
            raise UnservedRequestError(
                f"request {self.rid} was not served by the drain — "
                "was the service's engine drained directly?")
        return self._value

    def exception(self) -> Optional[SynthesisError]:
        """The typed error this request resolved to, or None if it
        produced rows.  Drains (once) if the request is still pending,
        mirroring ``result()``."""
        if not self.done():
            self._service.drain()
        return self._error

    def __repr__(self):
        state = ("failed" if self._error is not None
                 else "done" if self._value is not None else "pending")
        return f"SynthesisFuture(rid={self.rid}, {state})"


class SynthesisService:
    """Futures + streaming drains + persistent store over one engine."""

    def __init__(self, engine: SynthesisEngine, *,
                 key: jax.Array | int | None = None,
                 store: SynthesisStore | str | None = None,
                 ragged: bool | None = None,
                 compaction: int | str | None = None,
                 topology=None, hosts: int | None = None,
                 store_max_bytes: int | None = None,
                 tracer: Tracer | None = None,
                 faults: FaultInjector | None = None,
                 retry: RetryPolicy | None = None):
        """``ragged`` (opt-in) switches the engine to ragged waves: every
        classifier-free group shares one compiled per-row (guidance,
        steps) trajectory — see ``SynthesisEngine``.  Cache and store
        keys are unchanged, so a warm store serves both modes.

        ``compaction`` (opt-in; implies ragged) additionally runs each
        merged wave as iteration-compacted nested segments — frozen rows
        stop riding the denoiser — with results still bit-identical to
        the one-shot ragged wave: ``"full"``, ``"auto"``, or an
        epoch-count cap K.  Opt-in only: ``"off"`` is IGNORED here so
        wrapping a shared engine never forces its mode back — disable
        directly via ``engine.set_compaction("off")``.

        ``topology`` (a ``serve/topology.py::HostTopology``) or ``hosts``
        (an int H) places drains over a multi-host topology: per-host
        ingress queues, per-host wave windows against one wave-resident
        scalar table, per-host stats — with D_syn bit-identical to any
        other host count or placement.  Opt-in only, like the other two.

        ``store_max_bytes`` is the persistent store's size budget: after
        every drain the least-recently-used shards are evicted until the
        store fits (a long-lived server stops growing without bound).

        ``tracer`` (an ``obs/trace.py::Tracer``) records every drain's
        span timeline and request lifecycle; the service derives
        ``request.queue_wait`` / ``request.e2e_latency`` histograms from
        the stamps after each drain.  Opt-in only, like the other knobs.

        ``faults`` / ``retry`` (``serve/faults.py``) thread a fault
        injector and a retry policy through the engine and its store —
        transient faults retry, a lost host fails over, and permanent
        failures resolve the affected futures to typed errors.  Opt-in
        only, like the other knobs.
        """
        if store is not None and not isinstance(store, SynthesisStore):
            store = SynthesisStore(store)
        if store is not None:
            engine.store = store
        engine.opt_in(ragged=ragged, compaction=compaction,
                      topology=topology, hosts=hosts, tracer=tracer,
                      faults=faults, retry=retry)
        self.engine = engine
        self.store = engine.store
        self.store_max_bytes = store_max_bytes
        self._evicted_entries = 0
        self._observed: set[int] = set()   # rids whose latencies are recorded
        if key is None:
            key = jax.random.PRNGKey(0)
        elif isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self._base_key = key
        self._drain_i = 0
        # rid -> future, weakly: a discarded future (callers consuming
        # drain()'s return map instead) costs no retained images
        self._futures: "weakref.WeakValueDictionary[int, SynthesisFuture]" \
            = weakref.WeakValueDictionary()
        self._drain_lock = threading.Lock()    # one drain at a time
        self._submit_lock = threading.Lock()   # rid assignment atomicity

    # -- submission (any thread) ------------------------------------------
    def _register(self, rid: int) -> SynthesisFuture:
        fut = SynthesisFuture(self, rid)
        self._futures[rid] = fut
        return fut

    def _deliver(self, rid: int, rows: np.ndarray):
        fut = self._futures.get(rid)
        if fut is not None:
            fut._value = rows

    def _deliver_error(self, rid: int, err: Exception):
        fut = self._futures.get(rid)
        if fut is not None:
            fut._error = err

    def submit(self, encoding, category: int, count: int | None = None, *,
               guidance: float | None = None,
               num_steps: int | None = None) -> SynthesisFuture:
        with self._submit_lock:
            rid = self.engine.submit(encoding, category, count,
                                     guidance=guidance, num_steps=num_steps)
            return self._register(rid)

    def submit_classifier_guided(self, logprob_fn, category: int, count: int,
                                 *, guidance: float | None = None,
                                 num_steps: int | None = None,
                                 group: Any = None) -> SynthesisFuture:
        with self._submit_lock:
            rid = self.engine.submit_classifier_guided(
                logprob_fn, category, count, guidance=guidance,
                num_steps=num_steps, group=group)
            return self._register(rid)

    def submit_unconditional(self, count: int, *, category: int = -1,
                             num_steps: int | None = None) -> SynthesisFuture:
        with self._submit_lock:
            rid = self.engine.submit_unconditional(count, category=category,
                                                   num_steps=num_steps)
            return self._register(rid)

    # -- draining ---------------------------------------------------------
    def drain(self, key=None, *, poll: Callable[[], bool] | None = None,
              host_polls: dict[int, Callable[[], bool]] | None = None,
              stream: bool | None = None) -> dict[int, np.ndarray]:
        """Drain queued requests, resolving their futures.

        ``key`` defaults to the next key in the service's deterministic
        drain-key stream.  ``poll`` is forwarded to the engine: it is
        invoked before each wave is packed and may submit new requests —
        compatible ones join the open wave (return falsy once the arrival
        trace is exhausted, or the drain never concludes).
        ``host_polls`` (requires the engine to have a topology) adds
        PER-HOST admission hooks on the same contract — every live
        host's hook runs at each wave boundary, a dead host's hook is
        dropped; see ``SynthesisEngine.run``.

        Failure contract: a PERMANENT failure inside one wave group
        resolves that group's futures to ``RequestFailedError`` (read
        via ``exception()``; ``result()`` raises it) while every other
        group keeps serving — one poisoned request never takes down the
        drain for every tenant.  Transient faults retry and a lost host
        fails over inside the engine, invisibly to futures.
        """
        with self._drain_lock:
            if key is None:
                key = jax.random.fold_in(self._base_key, self._drain_i)
            self._drain_i += 1
            # futures resolve as each wave retires (the per-drain
            # on_result hook), so requests served before a mid-drain
            # failure stay resolved even though run() raises; the return
            # value is the full drain's rid -> rows map
            try:
                return self.engine.run(key, poll=poll,
                                       host_polls=host_polls, stream=stream,
                                       on_result=self._deliver,
                                       on_error=self._deliver_error)
            finally:
                if (self.store is not None
                        and self.store_max_bytes is not None):
                    self._evicted_entries += len(
                        self.store.evict(self.store_max_bytes))
                self._observe_latencies()

    def _observe_latencies(self):
        """Fold each request's lifecycle stamps into the engine's
        ``request.queue_wait`` / ``request.e2e_latency`` histograms —
        once per rid, however many drains or gathers follow."""
        tr, m = self.engine.tracer, self.engine.metrics
        if not tr.enabled:
            return
        for rid in tr.lifecycle:
            if rid in self._observed:
                continue
            lat = tr.request_latency(rid)
            if "e2e_latency" not in lat:
                continue                    # still in flight
            self._observed.add(rid)
            m.observe("request.e2e_latency", lat["e2e_latency"])
            if "queue_wait" in lat:
                m.observe("request.queue_wait", lat["queue_wait"])

    def gather(self, futures: list[SynthesisFuture], key=None, *,
               return_exceptions: bool = False) -> list:
        """Results for ``futures`` in order, draining (once) if needed.
        Queue-wait and end-to-end latency for every request served so
        far land in the engine metrics as ``request.*`` histograms.

        With ``return_exceptions=True`` a failed future contributes its
        typed ``SynthesisError`` instead of raising, so one poisoned
        request doesn't hide every other result."""
        if any(not f.done() for f in futures):
            self.drain(key)
        self._observe_latencies()
        if not return_exceptions:
            return [f.result() for f in futures]
        out = []
        for f in futures:
            err = f.exception()
            out.append(err if err is not None else f.result())
        return out

    @property
    def stats(self) -> dict:
        s = dict(self.engine.stats)
        s["drains"] = self._drain_i
        s["store_entries"] = len(self.store) if self.store is not None else 0
        s["store_evicted"] = self._evicted_entries
        if self.engine.tracer.enabled:
            m = self.engine.metrics
            s["latency"] = {
                "queue_wait": m.get("request.queue_wait", default=None),
                "e2e_latency": m.get("request.e2e_latency", default=None)}
        return s
