"""Batched D_syn synthesis engine: wave-scheduled diffusion sampling.

The OSCAR server's hot path is generating D_syn from uploaded category
encodings (paper §IV, Eq. 8/9).  ``SynthesisEngine`` is the serving
substrate for that path, mirroring ``ServeEngine``'s wave scheduler for
the LM runtime:

* requests — (encoding, category, count) triples, or classifier-guided /
  unconditional variants — are expanded into per-sample conditioning rows
  and packed into NEAR-UNIFORM WAVES: for a group of N rows the engine
  picks one wave size ``w = ceil(N / ceil(N/wave_size) / g) * g`` so every
  wave of the group shares ONE compiled reverse trajectory (the seed-era
  per-method chunk loops compiled a fresh executable for every ragged tail
  shape) and padding is bounded by one granule per wave;
* wave batches are optionally sharded over the data axes of a mesh
  (``sharding/rules.py`` + ``launch/mesh.py``) — the granule is rounded up
  so every wave divides the data-parallel device count;
* per-encoding outputs are cached keyed by (encoding-hash, guidance,
  steps): resubmitting an encoding serves from cache and a larger count
  only generates the top-up rows (how benchmark sweeps over
  samples-per-category reuse earlier synthesis).

Waves are grouped by (mode, guidance, steps[, classifier identity]) —
classifier-guided requests batch per uploaded classifier, classifier-free
requests batch across every client and category in the queue.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.sampler import (sample_cfg, sample_classifier_guided,
                                     sample_uncond)
from repro.diffusion.schedule import NoiseSchedule


def _encoding_hash(encoding: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(encoding, np.float32)
                        .tobytes()).hexdigest()


@dataclass
class SynthesisRequest:
    rid: int
    mode: str                      # "cfg" | "clf" | "uncond"
    count: int
    category: int
    guidance: float
    num_steps: int
    cond: Optional[np.ndarray] = None      # (cond_dim,) for mode="cfg"
    logprob_fn: Optional[Callable] = None  # for mode="clf"
    group: Any = None                      # wave-affinity key for mode="clf"
    cache_key: Optional[tuple] = None


class SynthesisEngine:
    """Wave-based batched diffusion synthesis over a frozen DM."""

    def __init__(self, dm_params, dc: DiffusionConfig, sched: NoiseSchedule,
                 *, image_size: int, channels: int = 3, wave_size: int = 128,
                 eta: float = 1.0, use_pallas: bool = False, mesh=None,
                 cache: bool = True, granule: int = 8):
        self.dm_params, self.dc, self.sched = dm_params, dc, sched
        self.image_size, self.channels = image_size, channels
        self.eta, self.use_pallas = eta, use_pallas
        self.mesh = mesh
        self._data_sharding = None
        if mesh is not None:
            from repro.launch.mesh import mesh_axes
            ax = mesh_axes(mesh)
            data_names = ax.data
            dsize = int(np.prod([mesh.shape[n] for n in data_names]))
            granule = -(-granule // dsize) * dsize      # waves divide data axes
            self._data_sharding = NamedSharding(mesh, P(ax.all_data, None))
        self.granule = granule
        self.wave_size = max(-(-wave_size // granule) * granule, granule)
        self.cache_enabled = cache
        self._cache: dict[tuple, np.ndarray] = {}
        self._queue: list[SynthesisRequest] = []
        self._next_rid = 0
        self.stats = {"requests": 0, "waves": 0, "generated": 0,
                      "padded": 0, "cache_hits": 0}

    # -- submission -------------------------------------------------------
    def submit(self, encoding, category: int, count: int, *,
               guidance: float | None = None,
               num_steps: int | None = None) -> int:
        """Classifier-free request: ``count`` samples conditioned on one
        uploaded category encoding (paper Eq. 8/9)."""
        enc = np.ascontiguousarray(encoding, np.float32)
        g, steps = self._resolve(guidance, num_steps)
        ck = (_encoding_hash(enc), g, steps) if self.cache_enabled else None
        return self._push(SynthesisRequest(
            rid=-1, mode="cfg", count=int(count), category=int(category),
            guidance=g, num_steps=steps, cond=enc, cache_key=ck))

    def submit_classifier_guided(self, logprob_fn, category: int, count: int,
                                 *, guidance: float | None = None,
                                 num_steps: int | None = None,
                                 group: Any = None) -> int:
        """Classifier-guided request (Eq. 4 / FedCADO).  ``group`` is the
        wave-affinity key — requests sharing it (one uploaded classifier)
        batch into the same waves.  Not cached: a Python closure has no
        stable identity to key on."""
        g, steps = self._resolve(guidance, num_steps)
        # default group: unique per request — id(fn) is unstable under GC
        # and a collision would sample with the wrong classifier
        return self._push(SynthesisRequest(
            rid=-1, mode="clf", count=int(count), category=int(category),
            guidance=g, num_steps=steps, logprob_fn=logprob_fn,
            group=group if group is not None else ("anon", self._next_rid)))

    def submit_unconditional(self, count: int, *, category: int = -1,
                             num_steps: int | None = None) -> int:
        """Unguided p(x) draws through the null embedding."""
        _, steps = self._resolve(0.0, num_steps)
        return self._push(SynthesisRequest(
            rid=-1, mode="uncond", count=int(count), category=int(category),
            guidance=0.0, num_steps=steps))

    # -- draining ---------------------------------------------------------
    def run(self, key) -> dict[int, np.ndarray]:
        """Drain the queue.  Returns rid -> (count, H, W, C) images.

        Deterministic in ``key`` and the queue contents: wave ``i`` of the
        drain samples with ``fold_in(key, i)``.  Cached rows are returned
        as generated by the run that produced them.
        """
        results: dict[int, np.ndarray] = {}
        pending: list[SynthesisRequest] = []
        for r in self._queue:                      # serve from cache first
            served = self._from_cache(r)
            if served is not None:
                results[r.rid] = served
            else:
                pending.append(r)
        self._queue = []

        wave_i = 0
        for gkey in sorted({self._group_key(r) for r in pending}):
            grp = [r for r in pending if self._group_key(r) == gkey]
            wave_i = self._run_group(grp, key, wave_i, results)
        return results

    # -- internals --------------------------------------------------------
    def _resolve(self, guidance, num_steps):
        g = self.dc.guidance_scale if guidance is None else float(guidance)
        return g, int(num_steps or self.dc.sample_timesteps)

    def _push(self, req: SynthesisRequest) -> int:
        req.rid = self._next_rid
        self._next_rid += 1
        self._queue.append(req)
        self.stats["requests"] += 1
        return req.rid

    def _group_key(self, r: SynthesisRequest):
        clf = ("clf", repr(r.group)) if r.mode == "clf" else ("", "")
        return (r.mode, r.guidance, r.num_steps) + clf

    def _from_cache(self, r: SynthesisRequest):
        if r.cache_key is None:
            return None
        have = self._cache.get(r.cache_key)
        if have is not None and len(have) >= r.count:
            self.stats["cache_hits"] += r.count
            return have[:r.count].copy()
        return None

    def _plan_waves(self, n: int) -> tuple[int, int]:
        """(num_waves, wave_rows): near-uniform waves, one compiled shape
        per group, padding < one granule per wave."""
        nw = -(-n // self.wave_size)
        per_wave = -(-n // nw)
        rows = -(-per_wave // self.granule) * self.granule
        return nw, rows

    def _shard(self, arr):
        if self._data_sharding is None:
            return arr
        return jax.device_put(arr, self._data_sharding)

    def _sample_wave(self, grp_head: SynthesisRequest, cond_rows, key):
        H, C = self.image_size, self.channels
        if grp_head.mode == "cfg":
            return sample_cfg(self.dm_params, self.dc, self.sched,
                              self._shard(jnp.asarray(cond_rows)), key,
                              image_size=H, channels=C,
                              num_steps=grp_head.num_steps,
                              guidance=grp_head.guidance, eta=self.eta,
                              use_pallas=self.use_pallas)
        if grp_head.mode == "clf":
            return sample_classifier_guided(
                self.dm_params, self.dc, self.sched, grp_head.logprob_fn,
                self._shard(jnp.asarray(cond_rows, jnp.int32)), key,
                image_size=H, channels=C, num_steps=grp_head.num_steps,
                guidance=grp_head.guidance, eta=self.eta)
        return sample_uncond(self.dm_params, self.dc, self.sched,
                             len(cond_rows), key, image_size=H, channels=C,
                             num_steps=grp_head.num_steps, eta=self.eta)

    def _run_group(self, grp: list[SynthesisRequest], key, wave_i: int,
                   results: dict) -> int:
        head = grp[0]
        # top-up: only generate rows the cache doesn't already hold.
        # ``planned`` counts rows already scheduled THIS drain, so several
        # requests sharing a cache key generate their union once (they are
        # served the same rows — the cache's cross-drain semantics).
        fresh = []
        planned: dict[tuple, int] = {}
        for r in grp:
            have = 0
            if r.cache_key is not None:
                have = (len(self._cache.get(r.cache_key, ()))
                        + planned.get(r.cache_key, 0))
            f = max(r.count - have, 0)
            if r.cache_key is not None and f:
                planned[r.cache_key] = planned.get(r.cache_key, 0) + f
            fresh.append(f)
            self.stats["cache_hits"] += r.count - f
        n = sum(fresh)
        if head.mode == "cfg":
            rows = np.concatenate([
                np.repeat(r.cond[None], f, axis=0)
                for r, f in zip(grp, fresh) if f] or
                [np.zeros((0, self.dc.cond_dim), np.float32)])
        elif head.mode == "clf":
            rows = np.concatenate([
                np.full((f,), r.category, np.int32)
                for r, f in zip(grp, fresh) if f] or
                [np.zeros((0,), np.int32)])
        else:
            rows = np.zeros((n,), np.int32)       # placeholder row ids

        outs = np.zeros((0, self.image_size, self.image_size, self.channels),
                        np.float32)
        if n:
            nw, wrows = self._plan_waves(n)
            total = nw * wrows
            if total > n:                          # pad by repeating tail row
                rows = np.concatenate([rows, np.repeat(rows[-1:],
                                                       total - n, axis=0)])
            self.stats["padded"] += total - n
            self.stats["generated"] += total
            wave_out = []
            for w in range(nw):
                kw = jax.random.fold_in(key, wave_i)
                wave_i += 1
                x = self._sample_wave(head, rows[w * wrows:(w + 1) * wrows],
                                      kw)
                wave_out.append(np.asarray(x))
                self.stats["waves"] += 1
            outs = np.concatenate(wave_out)[:n]

        # scatter rows back to requests (+ cache append)
        off = 0
        for r, f in zip(grp, fresh):
            new = outs[off:off + f]
            off += f
            if r.cache_key is not None:
                have = self._cache.get(r.cache_key)
                self._cache[r.cache_key] = (new if have is None
                                            else np.concatenate([have, new]))
                results[r.rid] = self._cache[r.cache_key][:r.count].copy()
            else:
                results[r.rid] = new
        return wave_i
