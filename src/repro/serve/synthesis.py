"""Batched D_syn synthesis engine: wave-scheduled diffusion sampling.

The OSCAR server's hot path is generating D_syn from uploaded category
encodings (paper §IV, Eq. 8/9).  ``SynthesisEngine`` is the serving
substrate for that path, mirroring ``ServeEngine``'s wave scheduler for
the LM runtime:

* requests — (encoding, category, count) triples, or classifier-guided /
  unconditional variants — are expanded into per-sample conditioning rows
  held in LIVE PER-GROUP QUEUES; the wave packer peels rows off a group's
  queue one wave at a time, so requests admitted mid-drain (streaming
  mode) fill partially-empty waves instead of forcing padding;
* in snapshot mode (``run`` without ``poll``) a group of N rows is packed
  into NEAR-UNIFORM WAVES: one wave size
  ``w = ceil(N / ceil(N/wave_size) / g) * g`` so every wave of the group
  shares ONE compiled reverse trajectory (the seed-era per-method chunk
  loops compiled a fresh executable for every ragged tail shape) and
  padding is bounded by one granule per wave;  in streaming mode waves
  are ``wave_size`` rows and only the final tail is rounded (down) to a
  granule multiple — less padding at the cost of one extra tail shape;
* waves are DOUBLE-BUFFERED: wave k+1's host-side row packing and
  ``device_put`` overlap wave k's device step loop; the host fences on
  ``jax.block_until_ready`` only when retiring wave k, so packing cost
  disappears from the critical path (disable with ``async_waves=False``);
* wave batches are optionally sharded over the data axes of a mesh
  (``sharding/rules.py`` + ``launch/mesh.py``) — the granule is rounded up
  so every wave divides the data-parallel device count;
* per-encoding outputs are cached keyed by (encoding-hash, guidance,
  steps): resubmitting an encoding serves from cache and a larger count
  only generates the top-up rows (how benchmark sweeps over
  samples-per-category reuse earlier synthesis).  With a persistent
  ``serve/store.py::SynthesisStore`` attached the cache spills to disk,
  so a cold process serves repeated workloads with zero sampler calls.

In GROUPED mode waves are grouped by (mode, guidance,
steps[, classifier identity]) — classifier-guided requests batch per
uploaded classifier, classifier-free requests batch across every client
and category in the queue.

RAGGED WAVES (``ragged=True``): guidance scale and step count become
PER-ROW, and EVERY guidance mode merges into ONE live queue — cfg,
classifier-guided, and unconditional requests share waves instead of
each padding and compiling their own.  One compiled
(wave_rows, max_steps) trajectory serves a mixed (mode, guidance,
steps, classifier) workload: the guidance sweep's groups, FedDISC's
resampled-statistics requests, OSCAR's uploads, FedCADO-style uploaded
classifiers, and unguided draws all ride the same waves.  Unconditional
rows are the s=0 degenerate point of the cfg combine with an explicit
null conditioning row (bit-identical to ``dit_apply``'s y=None
broadcast); classifier-guided rows carry a slot into the engine's
classifier-ensemble registry, and the wave's per-row ε̂-correction
(Eq. 4) selects each row's classifier by that slot — per-sample
classifier evaluations, so a row's value is independent of what else is
batched with it.  A wave with no classifier rows dispatches the pure
cfg executable (grouped-uncond waves count stays zero either way).
Shorter-step rows are right-aligned inside the shared scan and frozen
by an active mask until their trajectory starts; each row's noise
stream is keyed by ``fold_in(fold_in(drain_key, rid), row_index)`` —
the row's identity, not its wave position or mode neighborhood — so
results are bit-independent of how the packer interleaved modes,
streamed arrivals, or padded the wave, and bit-identical to the same
engine serving each mode in isolation.  Cache/store keys stay
(encoding-hash, guidance, steps) (uncond: a synthetic per-category
key), so ragged and grouped engines share a warm store transparently.

COMPACTION (``compaction="auto" | "full" | K``, implies ``ragged``): the
one-shot ragged scan still runs every row through the wave's full step
ceiling — frozen right-aligned rows ride the denoiser before they
activate (the ``row_iters_scheduled`` vs ``row_iters_active`` gap).  A
compacted wave instead runs one scan SEGMENT per activation epoch
(``diffusion/guidance.py::plan_epochs``): rows sorted by start iteration,
each segment's batch holding only the rows live by its end — nested
waves that grow as rows activate — and segment outputs stitched back
into request order.  Row noise stays keyed by request identity, so
compacted output is BIT-IDENTICAL to ragged (and to any other packing);
only the schedule changes.  ``"full"`` puts a boundary at every distinct
start (scheduled == active == the true sum of per-row steps); an int
caps the epoch count; ``"auto"`` keeps a boundary when the frozen
row-iterations it saves outweigh ``compaction_compile_cost``, consulting
the engine's shape-bucket cache of already-compiled segment geometries
(``(carried, rows, iterations)``) so a split that reuses an executable
from an earlier wave or drain is free.

TOPOLOGY (``topology=HostTopology(...)`` or ``hosts=H``): the drain is
placed over H hosts instead of one monolithic packer
(``serve/topology.py``).  Every classifier-free request is routed to a
host's INGRESS QUEUE by its identity (``rid % H``); each host packs its
own contiguous WINDOW of every wave locally (padding is per-window), and
the wave's per-row (ᾱ_t, ᾱ_prev, s, active) scalars live in ONE
wave-resident table that each window's scan reads through the
segment-offset ``cfg_fuse`` path (``cfg_update_rowwise(row_offset=
window.offset)``) — no per-host sliced copies.  Under a topology every
cfg wave (grouped OR ragged) samples row-keyed, so D_syn is
BIT-IDENTICAL regardless of host count, placement, or arrival order —
and identical to a plain ``ragged=True`` engine serving the same
requests.  Compaction composes per window: each host activation-sorts
and epoch-plans its own window, so its segments stay contiguous
row-windows of the wave table.  Multi-host is SIMULATED in one process
(host partitions of the local device set); per-host device placement on
a real pod hangs off ``HostTopology.mesh`` / ``host_submesh``.
Under ragged scheduling EVERY mode places (classifier-guided and uncond
rows ride the merged waves, so they shard by rows like any cfg row —
the per-row correction batches the classifier over the window); in
grouped mode clf/uncond groups keep the single-host path.  Per-host
accounting lands in ``stats["per_host"]``.

CONCURRENT PLACED DRAIN (``workers=True``, the default): every live
host gets its own EXECUTOR THREAD (``_HostPool``), and a placed wave
runs in two parallel phases — each host packs its window on its own
worker (``np.concatenate``, meta building, ``plan_epochs``, all
overlapping other hosts' work), then, after the wave-resident table is
assembled, each host dispatches its window's jitted segment chain on
its worker WITHOUT fencing.  Retirement fences every window
concurrently on its host's worker, so a ``device.scan`` span times only
its own host's wait (the sequential drain fenced in window order — host
1's span silently measured host 0's).  Concurrency is VALUE-INVISIBLE:
row noise is keyed by request identity and scatter order is fixed by
the placement, so D_syn is bit-identical under any thread interleaving
— and to the ``workers=False`` sequential oracle.  A ``HostLostError``
raised inside a worker (the ``window`` fault site fires there) is
marshalled back to the drain loop after every in-flight dispatch is
collected, and takes the same ``_handle_host_loss`` failover path;
hosts lost CONCURRENTLY in one wave ride along on the first error.

PER-HOST STREAMING ADMISSION (``run(host_polls={h: hook})``): each
host's frontend can poll its own arrival trace — every hook runs at
every wave boundary (it may submit; identity routing places the
request), and any hook returning truthy keeps the drain alive when the
queues run dry, exactly like the global ``poll``.

Requests stay on the queue until their results are produced OR they
resolve to a typed failure: an exception mid-drain (a failing sampler,
an interrupted process) leaves every unserved request queued for the
next ``run``, and rows already produced by the failed drain are CARRIED
to that next ``run`` — exception → re-drain serves every admitted
request with zero loss, whether or not the caller streamed results
through ``on_result``.

FAULT TOLERANCE (``faults=FaultInjector(...)``, ``retry=RetryPolicy()``,
``serve/faults.py``): the drain checks injectable fault SITES —
``window`` (host-window dispatch), ``scan`` (the device fence) — and
recovers instead of aborting.  A transient scan fault retries under the
engine's ``RetryPolicy``; a lost host (``HostLostError`` from a window
dispatch) triggers FAILOVER: ``topology.mark_failed`` removes it, the
aborted wave's rows are un-taken back onto their queues, the dead host's
admitted requests migrate to survivors' ingress queues, and the drain
re-quotas through the same ``wave_quotas``/``WavePlacement.plan`` path.
D_syn stays bit-identical to the fault-free run under ANY fault
schedule because row noise is keyed by request identity — failover is a
placement change, not a resample.  With ``run(on_error=...)`` a
PERMANENT group failure (e.g. a poisoned classifier closure) is
isolated: every unserved request of that group resolves to a
``RequestFailedError`` through the hook and the drain continues serving
other groups.
"""
from __future__ import annotations

import hashlib
import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.guidance import plan_epochs, ragged_tables
from repro.diffusion.sampler import (_window_segment, _window_segment_mixed,
                                     sample_cfg, sample_cfg_compacted,
                                     sample_cfg_ragged,
                                     sample_classifier_guided, sample_mixed,
                                     sample_mixed_compacted, sample_uncond)
from repro.diffusion.schedule import NoiseSchedule
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve.faults import (AllHostsLostError, FaultInjector,
                                HostLostError, RequestFailedError,
                                RetryPolicy)
from repro.serve.topology import HostTopology, WavePlacement


def _encoding_hash(encoding: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(encoding, np.float32)
                        .tobytes()).hexdigest()


@dataclass
class SynthesisRequest:
    rid: int
    mode: str                      # "cfg" | "clf" | "uncond"
    count: int
    category: int
    guidance: float
    num_steps: int
    cond: Optional[np.ndarray] = None      # (cond_dim,) for mode="cfg"
    logprob_fn: Optional[Callable] = None  # for mode="clf"
    group: Any = None                      # wave-affinity key for mode="clf"
    cache_key: Optional[tuple] = None


@dataclass
class _Pending:
    """A request admitted into a drain: ``fresh`` rows still to generate
    (count minus cache/planned coverage), packed into waves row by row."""
    req: SynthesisRequest
    fresh: int
    taken: int = 0                               # rows handed to waves
    chunks: list = field(default_factory=list)   # retired output slices

    def rows_left(self) -> int:
        return self.fresh - self.taken

    def row_block(self, k: int, start: int, null=None) -> np.ndarray:
        """Rows ``start:start+k`` of this request's fresh conditioning.
        A 1-D cfg encoding repeats one row; a 2-D encoding (one DISTINCT
        conditioning per sample, e.g. FedDISC's resampled statistics)
        slices — offset past the cached prefix, which covered the leading
        rows.  ``null`` (the DM's null conditioning row) is passed on the
        MERGED ragged path, where clf/uncond rows ride cfg waves as
        explicit null-cond rows (``dit_apply(y=None)`` broadcasts the
        same row, so the values are bit-identical); without it the legacy
        grouped packers get their int label/placeholder blocks."""
        r = self.req
        if r.mode == "cfg":
            if r.cond.ndim == 2:
                off = r.count - self.fresh + start
                return r.cond[off:off + k]
            return np.repeat(r.cond[None], k, axis=0)
        if null is not None:
            return np.repeat(null[None], k, axis=0)
        if r.mode == "clf":
            return np.full((k,), r.category, np.int32)
        return np.zeros((k,), np.int32)          # uncond placeholder ids

    def done_rows(self) -> int:
        return sum(len(c) for c in self.chunks)


class _GroupQueue:
    """Live FIFO of pending requests sharing one wave group — the packer
    consumes from here, so admissions mid-drain extend open waves."""

    def __init__(self, head: SynthesisRequest):
        self.head = head                          # defines mode/g/steps/clf
        self.items: deque[_Pending] = deque()
        # every pending ever pushed here: ``take`` pops exhausted items
        # off the live deque, so failure handling needs this registry to
        # enumerate the group's full admitted population
        self.admitted: list[_Pending] = []

    def push(self, p: _Pending):
        self.items.append(p)
        if not any(q is p for q in self.admitted):
            self.admitted.append(p)

    def rows_available(self) -> int:
        return sum(p.rows_left() for p in self.items)

    def take(self, k: int) -> list[tuple[_Pending, int, int]]:
        """Peel up to ``k`` rows off the queue front, FIFO.  Returns
        (pending, rows_taken, start_row) triples."""
        parts: list[tuple[_Pending, int, int]] = []
        while k > 0 and self.items:
            p = self.items[0]
            t = min(p.rows_left(), k)
            if t:
                parts.append((p, t, p.taken))
                p.taken += t
                k -= t
            if p.rows_left() == 0:
                self.items.popleft()
        return parts


class _ShardedGroup:
    """Per-host ingress for one wave group under a topology: one live
    ``_GroupQueue`` per host, so each host packs its window of a placed
    wave from its own queue (and streams its own late arrivals)."""

    def __init__(self, head: SynthesisRequest, num_hosts: int):
        self.head = head
        self.queues = [_GroupQueue(head) for _ in range(num_hosts)]

    def push(self, p: _Pending, host: int):
        self.queues[host].push(p)

    def rows_available(self) -> int:
        return sum(q.rows_available() for q in self.queues)


class _HostPool:
    """One single-thread executor per live host — the concurrency
    substrate of the placed drain.  A host's pack / dispatch / fence
    tasks run IN ORDER on its own worker (per-host FIFO preserves the
    dispatch-before-fence pipeline), while different hosts' tasks
    overlap freely.  ``discard`` retires exactly one host's worker
    (failover: survivors' threads are untouched); ``close`` joins
    everything at drain end."""

    def __init__(self, hosts):
        self._ex = {h: ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"synth-host{h}")
            for h in sorted(hosts)}

    @property
    def hosts(self) -> frozenset:
        return frozenset(self._ex)

    def submit(self, host: int, fn, *args):
        return self._ex[host].submit(fn, *args)

    def discard(self, host: int):
        """Retire one host's worker (called with no task in flight —
        the drain collects every future before handling a loss)."""
        ex = self._ex.pop(host, None)
        if ex is not None:
            ex.shutdown(wait=False)

    def close(self):
        for ex in self._ex.values():
            ex.shutdown(wait=True)
        self._ex = {}


class SynthesisEngine:
    """Wave-based batched diffusion synthesis over a frozen DM."""

    def __init__(self, dm_params, dc: DiffusionConfig, sched: NoiseSchedule,
                 *, image_size: int, channels: int = 3, wave_size: int = 128,
                 eta: float = 1.0, use_pallas: bool = False, mesh=None,
                 cache: bool = True, granule: int = 8, store=None,
                 async_waves: bool = True, ragged: bool = False,
                 compaction: int | str | None = None,
                 compaction_compile_cost: int = 256,
                 topology: HostTopology | None = None,
                 hosts: int | None = None,
                 workers: bool = True,
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None,
                 faults: FaultInjector | None = None,
                 retry: RetryPolicy | None = None):
        self.dm_params, self.dc, self.sched = dm_params, dc, sched
        self.image_size, self.channels = image_size, channels
        self.eta, self.use_pallas = eta, use_pallas
        self.mesh = mesh
        self._data_sharding = None
        if mesh is not None:
            from repro.launch.mesh import mesh_axes
            ax = mesh_axes(mesh)
            data_names = ax.data
            dsize = int(np.prod([mesh.shape[n] for n in data_names]))
            granule = -(-granule // dsize) * dsize      # waves divide data axes
            self._data_sharding = NamedSharding(mesh, P(ax.all_data, None))
        self.granule = granule
        self.wave_size = max(-(-wave_size // granule) * granule, granule)
        self.cache_enabled = cache
        self.store = store                       # SynthesisStore | None
        self.async_waves = async_waves
        self.ragged = ragged
        self.compaction = None
        self.compaction_compile_cost = compaction_compile_cost
        if compaction is not None:
            self.set_compaction(compaction)
        self.topology = None
        # per-(window offset, wave width) shape buckets of compiled window-
        # segment geometries: a window executable additionally specializes
        # on its offset and the wave's table width, so "auto" free-split
        # hits must be keyed per window, not pooled with _segment_geoms
        self._window_geoms: dict[tuple, set] = {}
        self._host_shardings: dict[int, Optional[dict]] = {}
        self._cache: dict[tuple, np.ndarray] = {}
        self._queue: list[SynthesisRequest] = []
        self._next_rid = 0
        self.traj_shapes: set = set()    # distinct compiled wave geometries
        # shape-bucket cache of compiled compaction-segment geometries
        # ((carried, rows, iterations) — the jitted executable's key);
        # plan_epochs treats a split that lands in a bucket as
        # compile-free, so recurring wave shapes compact deeper
        self._segment_geoms: set[tuple] = set()
        # mixed-guidance waves compile their OWN segment executables (the
        # classifier-correction step changes the jaxpr), so their "auto"
        # free-split hits live in a separate bucket from the pure-cfg one
        self._segment_geoms_mixed: set[tuple] = set()
        # classifier-ensemble registry for MERGED ragged waves: uploaded
        # classifier closures, in admission order; a wave row selects its
        # classifier by slot index (meta), and the registry tuple is a
        # static argument of the mixed sampler.  Slots only grow — an
        # ensemble extension retraces, a repeat classifier reuses its slot
        self._clf_fns: list = []
        # the DM's null conditioning row: merged waves pack clf/uncond
        # rows as explicit null-cond rows (bit-identical to dit_apply's
        # y=None broadcast of the same parameter)
        self._null_row = np.asarray(dm_params["null_y"], np.float32)
        # observability: a disabled tracer is the default (near-zero-cost
        # no-op spans/stamps); every counter lives in the registry and
        # the legacy ``stats`` dict is a read-only VIEW over it
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # fault tolerance: an injector (tests/chaos drills) and the retry
        # policy transient faults run under; both injectable, no wall-clock
        self.faults = faults
        self.retry = retry if retry is not None else RetryPolicy()
        # rows produced by a drain that raised before returning — the next
        # ``run`` hands them to its caller (zero-loss retry contract)
        self._carried: dict[int, np.ndarray] = {}
        # concurrent placed drain: per-host workers (``_HostPool``), built
        # lazily per drain for the live host set; ``workers=False`` keeps
        # the sequential window loop (the fuzz suites' oracle)
        self.workers = workers
        self._pool: Optional[_HostPool] = None
        # test seam: called as (site, host, wave) from inside worker
        # tasks, so tests can force thread interleavings with a barrier
        self._sync_hook = None
        if topology is not None or hosts is not None:
            self.set_topology(topology if topology is not None else hosts)

    #: legacy counter keys, in the order the pre-registry stats dict
    #: carried them — the view preserves both names and order bit-for-bit
    #: ``generated`` counts REAL rows only (images a caller asked for);
    #: ``scheduled_rows`` counts every device row including alignment
    #: padding — the invariant ``scheduled_rows == generated + padded``
    #: holds on every path (grouped/ragged/compacted/placed)
    _STAT_KEYS = ("requests", "waves", "generated", "scheduled_rows",
                  "padded", "cache_hits",
                  "store_hits", "streamed", "merged_waves",
                  "compiled_shapes", "segments",
                  "row_iters_scheduled", "row_iters_active")
    _HOST_STAT_KEYS = ("rows", "padded", "waves", "row_iters_scheduled",
                       "row_iters_active", "queue_depth_at_start")

    @property
    def stats(self) -> dict:
        """Backward-compatible dict view over the metrics registry: all
        pre-registry keys (including the per-host breakdown under a
        topology) with identical values.  A fresh dict per read — bump
        counters through ``self.metrics``, not this view."""
        m = self.metrics
        s = {k: m.get(k) for k in self._STAT_KEYS}
        if self.topology is not None:
            s["hosts"] = self.topology.num_hosts
            s["per_host"] = [
                {k: m.get(f"host.{k}", host=h)
                 for k in self._HOST_STAT_KEYS}
                for h in range(self.topology.num_hosts)]
        return s

    def set_topology(self, topology):
        """Normalize + apply the placement knob.  ``None`` leaves the
        topology alone; an int H builds one — from the engine's mesh when
        it has one (H host partitions of the data axes), otherwise H
        simulated hosts whose windows round to the engine granule.  Sets
        up the per-host stats breakdown (``stats["per_host"]``); the
        cross-host sums of rows/padded/row_iters equal the global
        counters for every placed (classifier-free) wave.  Re-applying
        an EQUAL topology is a no-op (a shared engine's ``opt_in`` runs
        once per entry point and must not wipe accumulated per-host
        counters); switching to a different topology resets the
        breakdown — counters from another layout cannot be merged."""
        if topology is None:
            return
        if isinstance(topology, bool) or not isinstance(
                topology, (int, HostTopology)):
            raise ValueError(
                f"topology={topology!r}: expected a HostTopology or an "
                f"int host count")
        if isinstance(topology, int):
            topology = (HostTopology.from_mesh(self.mesh, topology)
                        if self.mesh is not None else
                        HostTopology.simulated(topology,
                                               granule=self.granule))
        if topology == self.topology or (
                self.topology is not None
                and topology == replace(self.topology, failed=frozenset())):
            return            # re-threading the same placement (a shared
                              # engine's opt_in runs once per entry point)
                              # must not wipe the per-host accounting —
                              # nor resurrect hosts the engine has marked
                              # failed since the fleet was first threaded
        self.topology = topology
        self._host_shardings = {}
        # counters from another layout cannot be merged: drop the old
        # breakdown, then materialize zeroed counters for every host so
        # the stats view (and the metrics dump) lists each one
        self.metrics.drop("host.")
        self.metrics.set_gauge("hosts", topology.num_hosts)
        for h in range(topology.num_hosts):
            for k in self._HOST_STAT_KEYS:
                self.metrics.counter(f"host.{k}", host=h)

    def set_compaction(self, compaction):
        """Normalize + apply the compaction knob.  ``None`` leaves the
        mode alone; ``"off"`` disables; ``"full"``/``"auto"``/int K
        enable (compaction implies ragged waves — it schedules the ragged
        per-row tables)."""
        if compaction is None:
            return
        if compaction == "off":
            self.compaction = None
            return
        if compaction not in ("full", "auto") and (
                not isinstance(compaction, int) or isinstance(compaction, bool)
                or compaction < 1):
            raise ValueError(
                f"compaction={compaction!r}: expected 'off', 'full', "
                f"'auto', or an int K >= 1")
        self.compaction = compaction
        self.ragged = True

    def opt_in(self, *, ragged: bool | None = None, compaction=None,
               topology=None, hosts: int | None = None,
               tracer: Tracer | None = None,
               faults: FaultInjector | None = None,
               retry: RetryPolicy | None = None):
        """Thread scheduling knobs from a run entry point, OPT-IN ONLY:
        ``ragged=True`` switches this engine to ragged waves,
        ``compaction`` (``"full"``/``"auto"``/int K) enables compacted
        scheduling, ``topology``/``hosts`` places drains over a host
        topology, and ``tracer`` attaches a span/lifecycle tracer — but
        none of them ever forces a shared engine's mode back:
        ``ragged=False``/``None``, ``compaction="off"``/``None``,
        ``topology=None``/``hosts=None``, and ``tracer=None`` leave it
        alone here (disable directly via the attribute or the ``set_*``
        helpers).  This is THE contract every runner and the service
        constructor share; keep them on this helper."""
        if ragged:
            self.ragged = True
        if compaction != "off":
            self.set_compaction(compaction)
        self.set_topology(topology if topology is not None else hosts)
        if tracer is not None:
            self.tracer = tracer
        if faults is not None:
            self.faults = faults
        if retry is not None:
            self.retry = retry
        return self

    # -- submission -------------------------------------------------------
    def submit(self, encoding, category: int, count: int | None = None, *,
               guidance: float | None = None,
               num_steps: int | None = None) -> int:
        """Classifier-free request (paper Eq. 8/9).  A 1-D ``encoding``
        yields ``count`` samples of one conditioning row; a 2-D
        ``(count, cond_dim)`` encoding carries one DISTINCT conditioning
        per sample (e.g. FedDISC's resampled statistics) as a single
        request — and a single cache/store entry."""
        enc = np.ascontiguousarray(encoding, np.float32)
        if enc.ndim == 2:
            if count is not None and count != len(enc):
                raise ValueError(
                    f"2-D encoding carries {len(enc)} rows; count={count}")
            count = len(enc)
        elif count is None:
            raise ValueError("count is required for a 1-D encoding")
        g, steps = self._resolve(guidance, num_steps)
        ck = (_encoding_hash(enc), g, steps) if self.cache_enabled else None
        return self._push(SynthesisRequest(
            rid=-1, mode="cfg", count=int(count), category=int(category),
            guidance=g, num_steps=steps, cond=enc, cache_key=ck))

    def submit_classifier_guided(self, logprob_fn, category: int, count: int,
                                 *, guidance: float | None = None,
                                 num_steps: int | None = None,
                                 group: Any = None) -> int:
        """Classifier-guided request (Eq. 4 / FedCADO).  ``group`` is the
        wave-affinity key — requests sharing it (one uploaded classifier)
        batch into the same waves.  Not cached: a Python closure has no
        stable identity to key on."""
        g, steps = self._resolve(guidance, num_steps)
        # default group: unique per request — id(fn) is unstable under GC
        # and a collision would sample with the wrong classifier
        return self._push(SynthesisRequest(
            rid=-1, mode="clf", count=int(count), category=int(category),
            guidance=g, num_steps=steps, logprob_fn=logprob_fn,
            group=group if group is not None else ("anon", self._next_rid)))

    def submit_unconditional(self, count: int, *, category: int = -1,
                             num_steps: int | None = None) -> int:
        """Unguided p(x) draws through the null embedding.  Cached/stored
        like cfg requests under a synthetic per-category key (an uncond
        draw is fully determined by (category, steps) — there is no
        encoding to hash), so repeated uncond workloads replay from a
        warm store with zero sampler calls."""
        _, steps = self._resolve(0.0, num_steps)
        ck = ((f"uncond:{int(category)}", 0.0, steps)
              if self.cache_enabled else None)
        return self._push(SynthesisRequest(
            rid=-1, mode="uncond", count=int(count), category=int(category),
            guidance=0.0, num_steps=steps, cache_key=ck))

    # -- draining ---------------------------------------------------------
    def run(self, key, *, poll: Callable[[], bool] | None = None,
            host_polls: dict[int, Callable[[], bool]] | None = None,
            stream: bool | None = None,
            on_result: Callable[[int, np.ndarray], None] | None = None,
            on_error: Callable[[int, Exception], None] | None = None,
            ) -> dict[int, np.ndarray]:
        """Drain the queue.  Returns rid -> (count, H, W, C) images.

        Deterministic in ``key`` and the arrival trace: wave ``i`` of the
        drain samples with ``fold_in(key, i)``.  Cached rows are returned
        as generated by the run that produced them.

        ``poll`` (streaming mode) is called before each wave is packed and
        again before the drain concludes; it may submit new requests —
        compatible ones are packed into the currently-open wave.  Return
        truthy to keep the drain alive when the queue runs dry, falsy once
        the arrival trace is exhausted.  ``stream`` defaults to
        ``poll is not None or bool(host_polls)``; streaming packs
        ``wave_size``-row waves with a granule-rounded tail, snapshot mode
        packs near-uniform waves (one compiled shape per group).

        ``host_polls`` (requires a topology) maps host ids to PER-HOST
        poll hooks — each host's frontend polling its own arrival trace.
        Every live host's hook runs at every wave boundary alongside the
        global ``poll`` (a hook may submit; identity routing places the
        request on its home host's ingress queue), and any hook returning
        truthy keeps the drain alive when the queues run dry.  A hook
        whose host has FAILED is dropped, not called — its trace streams
        nowhere; resubmit through a live frontend.

        ``on_result`` (if given) is called with (rid, rows) the moment
        each request's results exist — this drain's caller (e.g. a
        SynthesisService resolving futures) keeps requests served BEFORE
        a mid-drain failure even though ``run`` raises.

        ``on_error`` (if given) turns a PERMANENT failure inside one wave
        group into per-request ``RequestFailedError``s delivered through
        the hook — the drain continues serving every other group instead
        of aborting (``AllHostsLostError`` still propagates: with no
        survivor nothing can make progress).  Without the hook the first
        group failure raises, preserving the legacy contract.

        Requests are removed from the queue only once their results (or a
        typed failure) are produced — an exception mid-drain keeps every
        unserved request queued, and CARRIES rows the failed drain did
        produce forward to the next ``run``, so exception → re-drain
        serves every admitted request with zero loss.
        """
        stream = ((poll is not None or bool(host_polls))
                  if stream is None else stream)
        if host_polls:
            if self.topology is None:
                raise ValueError("host_polls requires a topology "
                                 "(hosts=H / topology=HostTopology(...))")
            bad = [h for h in host_polls
                   if not 0 <= h < self.topology.num_hosts]
            if bad:
                raise ValueError(
                    f"host_polls hosts {bad} out of range for "
                    f"{self.topology.num_hosts} hosts")
        results: dict[int, np.ndarray] = {}
        failed: dict[int, Exception] = {}
        if self.store is not None:
            # store observability + fault policy ride the engine's —
            # shard I/O spans land on the exported store track
            self.store.bind(self.metrics, self.tracer,
                            faults=self.faults, retry=self.retry)
        if self._carried:
            # rows a previous drain produced but never returned (it
            # raised first): they belong to this run's caller now — the
            # finally block below already dropped their requests from
            # the queue when they were produced
            carried, self._carried = self._carried, {}
            results.update(carried)
            if on_result is not None:
                for rid, rows in carried.items():
                    on_result(rid, rows)
        with self.tracer.span("drain", queued=len(self._queue)):
            try:
                self._drain(key, results, failed, poll=poll,
                            host_polls=host_polls, stream=stream,
                            on_result=on_result, on_error=on_error)
            except BaseException:
                # this drain's caller never sees ``results`` — carry the
                # produced rows so the NEXT run returns them
                self._carried.update(results)
                raise
            finally:
                if self._pool is not None:
                    self._pool.close()     # join every host worker
                    self._pool = None
                if self.store is not None:
                    self.store.flush()
                # in-place removal, not a rebuild: a concurrent submit
                # from another thread (SynthesisService) may append
                # mid-removal and a rebuilt list would silently drop
                # that request
                for r in [r for r in self._queue
                          if r.rid in results or r.rid in failed]:
                    self._queue.remove(r)
        return results

    # -- internals --------------------------------------------------------
    def _resolve(self, guidance, num_steps):
        g = self.dc.guidance_scale if guidance is None else float(guidance)
        return g, int(num_steps or self.dc.sample_timesteps)

    def _push(self, req: SynthesisRequest) -> int:
        req.rid = self._next_rid
        self._next_rid += 1
        self._queue.append(req)
        self.metrics.inc("requests")
        self.tracer.stamp(req.rid, "admit")
        return req.rid

    def _group_key(self, r: SynthesisRequest):
        if self.ragged:
            # one merged super-group for EVERY guidance mode: per-row
            # (mode, guidance, steps, classifier) inside shared ragged
            # waves instead of one wave group per (mode, pair, closure).
            # uncond rows ride as s=0 null-cond cfg rows; clf rows carry
            # a slot into the engine's classifier-ensemble registry.
            # (The key literal stays ("cfg",) for continuity with the
            # cfg-only merged scheduler this generalizes.)
            return ("cfg",)
        clf = ("clf", repr(r.group)) if r.mode == "clf" else ("", "")
        return (r.mode, r.guidance, r.num_steps) + clf

    def _clf_slot(self, fn) -> int:
        """Slot of ``fn`` in the classifier-ensemble registry (identity
        match — closures are not hashable by value), appending on first
        sight.  New classifiers are registered at ADMISSION (drain
        thread), so wave packing — which may run on per-host workers —
        only ever performs read-only lookups."""
        for i, f in enumerate(self._clf_fns):
            if f is fn:
                return i
        self._clf_fns.append(fn)
        return len(self._clf_fns) - 1

    def _cached_rows(self, ck) -> Optional[np.ndarray]:
        """Memory cache, spilling in from the persistent store on miss."""
        rows = self._cache.get(ck)
        if rows is None and self.store is not None:
            rows = self.store.get(ck)
            if rows is not None:
                self._cache[ck] = rows
                self.metrics.inc("store_hits", len(rows))
        return rows

    def _plan_waves(self, n: int) -> tuple[int, int]:
        """(num_waves, wave_rows): near-uniform waves, one compiled shape
        per group, padding < one granule per wave."""
        nw = -(-n // self.wave_size)
        per_wave = -(-n // nw)
        rows = -(-per_wave // self.granule) * self.granule
        return nw, rows

    def _shard(self, arr):
        if self._data_sharding is None:
            return arr
        return jax.device_put(arr, self._data_sharding)

    def _note_shape(self, sig: tuple):
        """Track distinct compiled wave geometries (the jit-static part of
        a wave's sampler signature) — the benchmark's compile-count lens."""
        self.traj_shapes.add(sig)
        self.metrics.set_gauge("compiled_shapes", len(self.traj_shapes))

    def _row_keys(self, meta, key):
        """Per-row noise keys: ``fold_in(fold_in(drain_key, rid),
        row_index)`` — a function of the row's identity, NOT its wave
        position or schedule, so ragged and compacted waves (and any
        packing of either) draw identical streams for the same row."""
        rids = jnp.asarray([m[2] for m in meta], jnp.uint32)
        ridx = jnp.asarray([m[3] for m in meta], jnp.uint32)
        return jax.vmap(
            lambda r, i: jax.random.fold_in(jax.random.fold_in(key, r), i)
        )(rids, ridx)

    def _sample_wave_compacted(self, cond_rows, meta, key, max_steps: int):
        """One merged classifier-free wave, iteration-compacted: rows
        sorted by activation, one scan segment per epoch over only the
        live rows, outputs stitched back to request order.  Bit-identical
        to ``_sample_wave_ragged`` on the same rows (row noise is keyed
        by request identity); only the schedule — and therefore
        ``row_iters_scheduled`` — changes.  Returns
        ``(x, scheduled_iters)`` — scheduled counts every device row,
        padding included (it is device work); the caller accounts active
        iters over the real rows only."""
        g = np.array([m[0] for m in meta], np.float32)
        steps = np.array([m[1] for m in meta], np.int32)
        row_keys = self._row_keys(meta, key)
        seg_granule = self.granule if self.mesh is not None else 1
        plan = plan_epochs(steps, max_steps, compaction=self.compaction,
                           granule=seg_granule, geoms=self._segment_geoms,
                           compile_cost=self.compaction_compile_cost)
        _, epochs = plan
        prev = 0
        for rows, begin, end in epochs:
            # the full executable key — a jitted segment specializes on
            # (carried, live, iterations), and plan_epochs' "auto" cost
            # model checks exactly this tuple for free splits
            self._note_shape(("cfg-seg", prev, rows, end - begin))
            self._segment_geoms.add((prev, rows, end - begin))
            prev = rows
        self.metrics.inc("segments", len(epochs))
        x = sample_cfg_compacted(self.dm_params, self.dc, self.sched,
                                 self._shard(jnp.asarray(cond_rows)),
                                 row_keys, jnp.asarray(g), steps,
                                 max_steps=max_steps, plan=plan,
                                 image_size=self.image_size,
                                 channels=self.channels, eta=self.eta,
                                 use_pallas=self.use_pallas)
        scheduled = sum(rows * (end - begin) for rows, begin, end in epochs)
        return x, scheduled

    def _sample_wave_ragged(self, cond_rows, meta, key, max_steps: int):
        """One merged classifier-free wave.  ``meta`` carries one
        (guidance, steps, rid, absolute_row_index) per row; row noise keys
        are ``fold_in(fold_in(drain_key, rid), row_index)`` — a function
        of the row's identity, NOT its wave position, so outputs are
        independent of group interleaving, streaming arrival order, and
        alignment padding."""
        g = np.array([m[0] for m in meta], np.float32)
        steps = np.array([m[1] for m in meta], np.int32)
        row_keys = self._row_keys(meta, key)
        self._note_shape(("cfg-ragged", len(cond_rows), max_steps))
        return sample_cfg_ragged(self.dm_params, self.dc, self.sched,
                                 self._shard(jnp.asarray(cond_rows)),
                                 row_keys, jnp.asarray(g), steps,
                                 max_steps=max_steps,
                                 image_size=self.image_size,
                                 channels=self.channels, eta=self.eta,
                                 use_pallas=self.use_pallas)

    def _mixed_columns(self, meta):
        """The per-row mixed-guidance operands carried in meta columns
        4..6: (mode, clf slot, label) vectors plus the static ensemble
        tuple snapshot for this dispatch."""
        mode = np.array([m[4] for m in meta], np.float32)
        cids = np.array([m[5] for m in meta], np.int32)
        labels = np.array([m[6] for m in meta], np.int32)
        return mode, cids, labels, tuple(self._clf_fns)

    def _sample_wave_mixed(self, cond_rows, meta, key, max_steps: int):
        """One merged MIXED-guidance wave: ``_sample_wave_ragged`` plus
        per-row (mode, classifier slot, label) operands — cfg, classifier-
        guided and uncond rows share one launch and one compiled
        (wave_rows, max_steps, ensemble) executable.  Each row's value is
        bit-identical to the same merged engine serving that row's mode
        alone (row noise is identity-keyed and the per-row classifier
        correction is batch-composition-independent)."""
        g = np.array([m[0] for m in meta], np.float32)
        steps = np.array([m[1] for m in meta], np.int32)
        mode, cids, labels, clf_fns = self._mixed_columns(meta)
        row_keys = self._row_keys(meta, key)
        self._note_shape(("mixed-ragged", len(cond_rows), max_steps,
                          len(clf_fns)))
        return sample_mixed(self.dm_params, self.dc, self.sched,
                            self._shard(jnp.asarray(cond_rows)), row_keys,
                            jnp.asarray(g), mode, cids, labels, steps,
                            clf_fns=clf_fns, max_steps=max_steps,
                            image_size=self.image_size,
                            channels=self.channels, eta=self.eta,
                            use_pallas=self.use_pallas)

    def _sample_wave_mixed_compacted(self, cond_rows, meta, key,
                                     max_steps: int):
        """Iteration-compacted MIXED wave: ``_sample_wave_compacted``'s
        activation epochs with the mixed per-row operands riding along.
        Mixed segments compile their own executables (the classifier
        correction changes the jaxpr), so their "auto" free-split hits
        track in ``_segment_geoms_mixed``, not the pure-cfg bucket."""
        g = np.array([m[0] for m in meta], np.float32)
        steps = np.array([m[1] for m in meta], np.int32)
        mode, cids, labels, clf_fns = self._mixed_columns(meta)
        row_keys = self._row_keys(meta, key)
        seg_granule = self.granule if self.mesh is not None else 1
        plan = plan_epochs(steps, max_steps, compaction=self.compaction,
                           granule=seg_granule,
                           geoms=self._segment_geoms_mixed,
                           compile_cost=self.compaction_compile_cost)
        _, epochs = plan
        prev = 0
        for rows, begin, end in epochs:
            self._note_shape(("mixed-seg", prev, rows, end - begin,
                              len(clf_fns)))
            self._segment_geoms_mixed.add((prev, rows, end - begin))
            prev = rows
        self.metrics.inc("segments", len(epochs))
        x = sample_mixed_compacted(self.dm_params, self.dc, self.sched,
                                   self._shard(jnp.asarray(cond_rows)),
                                   row_keys, jnp.asarray(g), mode, cids,
                                   labels, steps, clf_fns=clf_fns,
                                   max_steps=max_steps, plan=plan,
                                   image_size=self.image_size,
                                   channels=self.channels, eta=self.eta,
                                   use_pallas=self.use_pallas)
        scheduled = sum(rows * (end - begin) for rows, begin, end in epochs)
        return x, scheduled

    def _sample_wave(self, grp_head: SynthesisRequest, cond_rows, key):
        H, C = self.image_size, self.channels
        if grp_head.mode == "cfg":
            self._note_shape(("cfg", len(cond_rows), grp_head.num_steps,
                              grp_head.guidance))
            return sample_cfg(self.dm_params, self.dc, self.sched,
                              self._shard(jnp.asarray(cond_rows)), key,
                              image_size=H, channels=C,
                              num_steps=grp_head.num_steps,
                              guidance=grp_head.guidance, eta=self.eta,
                              use_pallas=self.use_pallas)
        if grp_head.mode == "clf":
            self._note_shape(("clf", repr(grp_head.group), len(cond_rows),
                              grp_head.num_steps, grp_head.guidance))
            return sample_classifier_guided(
                self.dm_params, self.dc, self.sched, grp_head.logprob_fn,
                self._shard(jnp.asarray(cond_rows, jnp.int32)), key,
                image_size=H, channels=C, num_steps=grp_head.num_steps,
                guidance=grp_head.guidance, eta=self.eta,
                use_pallas=self.use_pallas)
        self._note_shape(("uncond", len(cond_rows), grp_head.num_steps))
        return sample_uncond(self.dm_params, self.dc, self.sched,
                             len(cond_rows), key, image_size=H, channels=C,
                             num_steps=grp_head.num_steps, eta=self.eta,
                             use_pallas=self.use_pallas)

    # -- drain machinery --------------------------------------------------
    def _drain(self, key, results, failed, *, poll, stream, host_polls=None,
               on_result=None, on_error=None):
        st = _DrainState()
        st.on_result = on_result
        st.on_error = on_error
        st.failed = failed
        st.tracer = self.tracer       # deliver stamps ride the drain state
        with self.tracer.span("drain.admit"):
            self._admit_new(st, results)
        st.started = True             # later admissions count as streamed
        if self.topology is not None:
            for h, q in enumerate(self._host_depths(st)):
                self.metrics.inc("host.queue_depth_at_start", q, host=h)
        polling = poll is not None or bool(host_polls)
        while True:
            live = sorted(g for g, q in st.groups.items()
                          if q.rows_available())
            if not live:
                if polling and self._poll_all(poll, host_polls):
                    self._admit_new(st, results)
                    continue
                break
            grp = st.groups[live[0]]
            try:
                if isinstance(grp, _ShardedGroup):
                    self._drain_group_placed(grp, st, key, results,
                                             poll=poll,
                                             host_polls=host_polls,
                                             stream=stream)
                else:
                    self._drain_group(grp, st, key, results, poll=poll,
                                      host_polls=host_polls, stream=stream)
            except Exception as exc:
                # failure isolation: with an on_error hook, a permanent
                # failure inside ONE group (a poisoned classifier, an
                # exhausted retry) fails that group's requests with typed
                # errors and the drain keeps serving everyone else.  No
                # hook → legacy contract: raise, keep queues intact.
                if st.on_error is None or isinstance(exc, AllHostsLostError):
                    raise
                self._fail_group(grp, st, results, exc)
        # any still-unresolved waiters are covered by rows generated above
        self._serve_waiters(st, results)

    def _host_depths(self, st: "_DrainState") -> list[int]:
        """Rows waiting on each host's ingress queues right now."""
        depths = [0] * self.topology.num_hosts
        for grp in st.groups.values():
            if isinstance(grp, _ShardedGroup):
                for h, q in enumerate(grp.queues):
                    depths[h] += q.rows_available()
        return depths

    def _poll_all(self, poll, host_polls) -> bool:
        """Admission keep-alive: run the global ``poll`` AND every live
        host's admission hook.  Every hook runs — no short-circuit,
        because a hook's side effect is submitting that host's requests
        — and any truthy return keeps the drain alive.  Hooks for hosts
        that have since died are dropped: their traffic belongs to
        survivors now, which identity routing over the live set already
        handles at admission."""
        more = False
        if poll is not None:
            more = bool(poll()) or more
        if host_polls:
            live = (self.topology.live_hosts
                    if self.topology is not None else ())
            for h, hook in host_polls.items():
                if h in live:
                    more = bool(hook()) or more
        return more

    def _ensure_pool(self) -> Optional[_HostPool]:
        """The per-host worker pool for the CURRENT live set, or None
        when the drain should stay sequential (``workers=False``, no
        topology, or fewer than two live hosts — one host gains nothing
        from a worker).  Rebuilt only when membership changes; a host
        loss discards just the dead host's executor
        (``_handle_host_loss``), so survivors' threads ride out the
        failover untouched."""
        if not self.workers or self.topology is None:
            return None
        live = frozenset(self.topology.live_hosts)
        if len(live) < 2:
            if self._pool is not None:
                self._pool.close()
                self._pool = None
            return None
        if self._pool is None or self._pool.hosts != live:
            if self._pool is not None:
                self._pool.close()
            self._pool = _HostPool(live)
        return self._pool

    @staticmethod
    def _collect(futs):
        """Gather per-window worker futures in WINDOW ORDER with
        deterministic error marshalling: every future is awaited (no
        task is left running into failover handling), then the first
        error BY WINDOW ORDER — not completion order — is raised,
        exactly what the sequential loop would have raised.  When that
        error is a ``HostLostError``, any further same-wave losses ride
        along as ``err.also`` so ``_handle_host_loss`` can fail every
        dead host from one aborted wave."""
        outs, first, losses = [], None, []
        for f in futs:
            try:
                outs.append(f.result())
            except HostLostError as err:
                losses.append(err)
                if first is None:
                    first = err
            except Exception as exc:          # noqa: BLE001 — re-raised
                if first is None:
                    first = exc
        if first is not None:
            if isinstance(first, HostLostError):
                first.also = [e for e in losses if e is not first]
            raise first
        return outs

    def _check_fault(self, site: str, *, host: int = 0, wave: int = -1):
        """Injectable fault site: counts what fires, then lets it raise."""
        if self.faults is None:
            return
        try:
            self.faults.check(site, host=host, wave=wave)
        except Exception:
            self.metrics.inc("fault.injected", site=site)
            raise

    def _fence(self, x, *, host: int, wave: int):
        """Retire-side device fence with the ``scan`` fault site under
        the engine's retry policy — a transient device hiccup burns
        retries instead of aborting the drain."""
        def attempt():
            self._check_fault("scan", host=host, wave=wave)
            jax.block_until_ready(x)
        self.retry.run(attempt, metrics=self.metrics, site="device.scan")

    def _fail_group(self, grp, st: "_DrainState", results, exc):
        """Resolve every unserved request admitted to ``grp`` to a typed
        ``RequestFailedError`` (cause attached) through the drain's
        ``on_error`` hook, release their cache-coverage claims, fail
        waiters riding a now-uncovered key, and clear the group's queues
        so the drain moves on."""
        queues = grp.queues if isinstance(grp, _ShardedGroup) else [grp]
        doomed = []
        for q in queues:
            for p in q.admitted:
                rid = p.req.rid
                if rid in results or rid in st.failed or \
                        any(d.req.rid == rid for d in doomed):
                    continue
                doomed.append(p)
        bad_keys = set()
        for p in doomed:
            r = p.req
            if r.cache_key is not None:
                # rows this pending claimed in ``planned`` will never be
                # generated; a same-key request must not count on them
                left = st.planned.get(r.cache_key, 0) - p.fresh
                st.planned[r.cache_key] = max(left, 0)
                bad_keys.add(r.cache_key)
            self._fail_request(st, r, exc)
        still = []
        for r in st.waiters:
            cached = self._cache.get(r.cache_key)
            covered = cached is not None and len(cached) >= r.count
            if r.cache_key in bad_keys and not covered:
                self._fail_request(st, r, exc)
            else:
                still.append(r)
        st.waiters = still
        for q in queues:
            q.items.clear()

    def _fail_request(self, st: "_DrainState", r: SynthesisRequest, exc):
        err = RequestFailedError(
            f"request {r.rid} ({r.mode}) failed permanently: {exc}",
            rid=r.rid)
        err.__cause__ = exc
        st.failed[r.rid] = err
        self.metrics.inc("requests_failed")
        self.tracer.instant("request.failed", rid=r.rid)
        if st.on_error is not None:
            st.on_error(r.rid, err)

    def _admit_new(self, st: "_DrainState", results):
        """Admission: serve full cache hits, compute top-up ``fresh`` row
        counts against cache + rows already planned this drain, and push
        the remainder onto their live group queues."""
        for r in list(self._queue):
            if r.rid in st.admitted:
                continue
            st.admitted.add(r.rid)
            if st.started:
                self.metrics.inc("streamed")
            if r.count <= 0:               # degenerate: nothing to generate
                st.deliver(results, r.rid, np.zeros(
                    (0, self.image_size, self.image_size, self.channels),
                    np.float32))
                continue
            have = 0
            if r.cache_key is not None:
                cached = self._cached_rows(r.cache_key)
                have = ((0 if cached is None else len(cached))
                        + st.planned.get(r.cache_key, 0))
            fresh = max(r.count - have, 0)
            self.metrics.inc("cache_hits", r.count - fresh)
            if fresh == 0:
                cached = self._cached_rows(r.cache_key)
                if cached is not None and len(cached) >= r.count:
                    st.deliver(results, r.rid, cached[:r.count].copy())
                else:
                    # covered by rows another request planned this drain —
                    # resolved once the generating wave retires
                    st.waiters.append(r)
                continue
            if r.mode == "clf" and self.ragged:
                # merged-path classifiers are vetted AT ADMISSION: an
                # abstract probe catches a poisoned closure before it is
                # baked into a mixed wave (where it would poison every
                # co-batched request), and registers the survivor's
                # ensemble slot while admission is still single-threaded.
                # With an on_error hook the bad request resolves to a
                # typed failure and the drain continues; without one the
                # legacy first-failure-raises contract holds.
                try:
                    H, C = self.image_size, self.channels
                    jax.eval_shape(
                        r.logprob_fn,
                        jax.ShapeDtypeStruct((1, H, H, C), jnp.float32),
                        jax.ShapeDtypeStruct((1,), jnp.int32))
                    self._clf_slot(r.logprob_fn)
                except Exception as exc:
                    if st.on_error is None:
                        raise
                    self._fail_request(st, r, exc)
                    continue
            if r.cache_key is not None:
                st.planned[r.cache_key] = (st.planned.get(r.cache_key, 0)
                                           + fresh)
            gk = self._group_key(r)
            placed = self.topology is not None and (r.mode == "cfg"
                                                    or self.ragged)
            if gk not in st.groups:
                st.groups[gk] = (_ShardedGroup(r, self.topology.num_hosts)
                                 if placed else _GroupQueue(r))
            self.tracer.stamp(r.rid, "enqueue")
            if placed:
                # ingress routing keyed by request IDENTITY, not arrival
                # order: a replayed trace lands every request on the same
                # host (and any routing is value-invisible anyway — row
                # noise is keyed by the row, not its host)
                st.groups[gk].push(_Pending(r, fresh),
                                   self.topology.assign(r.rid))
            else:
                st.groups[gk].push(_Pending(r, fresh))

    def _drain_group(self, q: _GroupQueue, st: "_DrainState", key, results,
                     *, poll, host_polls, stream):
        """Drain one group's live queue wave by wave, double-buffered:
        wave k+1 is packed and dispatched while wave k runs on device.
        Under ragged scheduling the one merged queue carries EVERY
        guidance mode; a wave with classifier-guided rows dispatches
        through the mixed sampler, a wave without any rides the pure
        cfg path (uncond rows are s=0 null-cond cfg rows there — the
        same arithmetic bit-for-bit)."""
        ragged = self.ragged
        if stream:
            wave_rows = self.wave_size
        else:
            _, wave_rows = self._plan_waves(q.rows_available())
        # ragged step ceiling: a running max, so every wave after the
        # deepest row arrives shares one compiled geometry (row results
        # are max_steps-independent — right-aligned rows just freeze
        # longer), and a drain sees at most one recompile per new deepest
        # step count instead of one per (guidance, steps) group
        smax = 0
        inflight = None                  # (device x, parts, n_real, wave)
        while True:
            # admission runs at every wave boundary with or without a
            # poll, so requests submitted by another thread while waves
            # are in flight stream into this drain too
            self._poll_all(poll, host_polls)
            self._admit_new(st, results)
            parts = q.take(wave_rows)
            got = sum(t for _, t, _ in parts)
            if got == 0:
                break
            if got < wave_rows:
                # open wave: give late arrivals one chance to fill it
                self._poll_all(poll, host_polls)
                self._admit_new(st, results)
                more = q.take(wave_rows - got)
                parts += more
                got += sum(t for _, t, _ in more)
            # tail: snapshot keeps the group-uniform shape, streaming
            # rounds to a granule multiple (one extra compiled tail shape)
            target = (-(-got // self.granule) * self.granule if stream
                      else wave_rows)
            with self.tracer.span("wave.pack", wave=st.wave_i, host=0,
                                  rows=target, real=got):
                rows = np.concatenate(
                    [p.row_block(t, s, self._null_row if ragged else None)
                     for p, t, s in parts])
                meta = None
                if ragged:
                    # (guidance, steps, rid, absolute row index, mode,
                    # clf slot, label) per row; the index offsets past
                    # the cached prefix so a top-up row has the same
                    # identity whichever drain generates it.  mode is
                    # 0 for cfg AND uncond (uncond = s=0 null-cond),
                    # 1 for classifier-guided; slot indexes the engine's
                    # classifier-ensemble registry
                    meta = [(p.req.guidance, p.req.num_steps, p.req.rid,
                             p.req.count - p.fresh + s + i,
                             1.0 if p.req.mode == "clf" else 0.0,
                             (self._clf_slot(p.req.logprob_fn)
                              if p.req.mode == "clf" else 0),
                             p.req.category)
                            for p, t, s in parts for i in range(t)]
                if target > got:
                    rows = np.concatenate(
                        [rows, np.repeat(rows[-1:], target - got, axis=0)])
                    if ragged:
                        # padding duplicates the last row's identity: same
                        # key, same cond — a discarded bit-identical copy
                        # that can never perturb the real rows
                        meta += [meta[-1]] * (target - got)
            for p, _, _ in parts:
                self.tracer.stamp(p.req.rid, "pack")
            kw = jax.random.fold_in(key, st.wave_i)
            st.wave_i += 1
            with self.tracer.span("wave.dispatch", wave=st.wave_i - 1,
                                  host=0, rows=target,
                                  mode=q.head.mode) as sp:
                if ragged:
                    smax = max(smax, *(m[1] for m in meta))
                    # honest device-work accounting, split two ways:
                    # ``row_iters_active`` is the useful work — each REAL
                    # row's own step count (padding duplicates are
                    # discarded, so they are never useful);
                    # ``row_iters_scheduled`` is what the device actually
                    # ran, padding included.  One-shot ragged schedules
                    # every row for the wave's step ceiling (frozen
                    # right-aligned rows ride the denoiser — the price of
                    # one shared geometry); compaction closes the gap by
                    # skipping frozen epochs.
                    active_iters = int(sum(m[1] for m in meta[:got]))
                    mixed = any(m[4] for m in meta)
                    if self.compaction is not None:
                        sampler = (self._sample_wave_mixed_compacted
                                   if mixed else self._sample_wave_compacted)
                        x, sched_iters = sampler(rows, meta, key, smax)
                    else:
                        sampler = (self._sample_wave_mixed if mixed
                                   else self._sample_wave_ragged)
                        x = sampler(rows, meta, key, smax)
                        sched_iters = target * smax
                    self.metrics.inc("merged_waves")
                    self.metrics.inc("row_iters_scheduled", sched_iters)
                    self.metrics.inc("row_iters_active", active_iters)
                    sp.set(iters_scheduled=sched_iters)
                else:
                    x = self._sample_wave(q.head, rows, kw)
                    self.metrics.inc("row_iters_scheduled",
                                     target * q.head.num_steps)
                    self.metrics.inc("row_iters_active",
                                     got * q.head.num_steps)
            for p, _, _ in parts:
                self.tracer.stamp(p.req.rid, "dispatch")
            self.metrics.inc("waves")
            self.metrics.inc("generated", got)
            self.metrics.inc("scheduled_rows", target)
            self.metrics.inc("padded", target - got)
            if inflight is not None:
                self._retire(st, results, *inflight)
            if self.async_waves:
                inflight = (x, parts, got, st.wave_i - 1)
            else:
                self._retire(st, results, x, parts, got, st.wave_i - 1)
        if inflight is not None:
            self._retire(st, results, *inflight)

    def _drain_group_placed(self, grp: _ShardedGroup, st: "_DrainState", key,
                            results, *, poll, host_polls, stream):
        """Placement-aware drain of one group (grouped cfg, or the
        merged all-modes ragged queue) over the engine's topology,
        double-buffered like ``_drain_group``: each host packs
        its contiguous window of every wave locally from its own ingress
        queue (per-window padding, per-window compaction plans), and the
        wave's per-row scalars live in one wave-resident table that every
        window reads through the segment-offset ``cfg_fuse`` path.
        Placed drains quota-pack in BOTH snapshot and streaming mode (the
        per-host quota split replaces ``_plan_waves``' near-uniform
        shapes); admission still runs at every wave boundary, so late
        arrivals stream into open windows either way.  Row noise stays
        keyed by request identity, so outputs are bit-identical for ANY
        topology, placement, or arrival order."""
        smax = 0                         # running step ceiling (see above)
        inflight = None                  # (xs, invs, placement, parts_h, w)
        shapes = set()                   # dispatched (host, rows) geometries
        # snapshot drains spread the group's rows over near-uniform waves
        # (the exact ``_plan_waves`` policy the single-host packer uses):
        # no systematic tail wave, so every wave shares the full waves'
        # window geometry and their compiled executables.  Streaming
        # drains can't know the total up front and keep ``wave_size``.
        if stream or grp.rows_available() == 0:
            wave_target = self.wave_size
        else:
            _, wave_target = self._plan_waves(grp.rows_available())
        while True:
            # re-read topology + quotas EVERY wave: a host lost on the
            # previous iteration re-spreads its share over survivors
            # through the same proportional split (failover == re-quota)
            topo = self.topology
            quotas = topo.wave_quotas(wave_target)
            self._poll_all(poll, host_polls)
            self._admit_new(st, results)
            parts_h = [q.take(quotas[h]) for h, q in enumerate(grp.queues)]
            got = sum(t for parts in parts_h for _, t, _ in parts)
            if got == 0:
                break
            if got < sum(quotas):
                # open wave: give late arrivals one chance to fill the
                # hosts' windows before padding them
                self._poll_all(poll, host_polls)
                self._admit_new(st, results)
                for h, q in enumerate(grp.queues):
                    have = sum(t for _, t, _ in parts_h[h])
                    if have < quotas[h]:
                        parts_h[h] += q.take(quotas[h] - have)
                got = sum(t for parts in parts_h for _, t, _ in parts)
            rows_h = [sum(t for _, t, _ in parts) for parts in parts_h]
            placement = WavePlacement.plan(rows_h, topo.granules)
            geom = tuple((w.host, w.rows) for w in placement.windows)
            if geom not in shapes:
                # tail-wave shape promotion: if padding every window up
                # to its quota reproduces a geometry this drain already
                # dispatched, take it — the tail then reuses the full
                # waves' compiled window executables instead of
                # compiling its own (padding dups are discarded at
                # scatter, so D_syn is unchanged)
                quota_pl = WavePlacement.plan(rows_h, topo.granules,
                                              pad_to=quotas)
                if tuple((w.host, w.rows)
                         for w in quota_pl.windows) in shapes:
                    placement = quota_pl
            # the wave index is BURNED only on successful dispatch (an
            # aborted wave's repack keeps the same index, so trace
            # ``wave=`` ids agree with the ``waves`` counter), and the
            # pack stamp is captured here but committed only after the
            # wave dispatches — first-stamp-wins tracer semantics must
            # not freeze an aborted wave's pack time
            wave = st.wave_i
            t_pack = self.tracer.now()
            deep = max(p.req.num_steps
                       for parts in parts_h for p, _, _ in parts)
            smax_w = max(smax, deep)
            try:
                xs, invs, host_stats = self._sample_wave_placed(
                    parts_h, placement, key, smax_w, wave=wave)
            except HostLostError as err:
                # FAILOVER: the in-flight wave was dispatched before the
                # loss — retire it first; then un-take this wave, migrate
                # the dead hosts' requests to survivors, and re-quota.
                # Row noise is identity-keyed, so the repacked rows are
                # bit-identical — a placement change, not a resample.
                if inflight is not None:
                    self._retire_placed(st, results, *inflight)
                    inflight = None
                self._handle_host_loss(grp, st, parts_h, err)
                continue
            st.wave_i += 1
            smax = smax_w
            shapes.add(tuple((w.host, w.rows) for w in placement.windows))
            for parts in parts_h:
                for p, _, _ in parts:
                    self.tracer.stamp(p.req.rid, "pack", t=t_pack)
                    self.tracer.stamp(p.req.rid, "dispatch")
            self.metrics.inc("waves")
            if self.ragged:
                self.metrics.inc("merged_waves")
            self.metrics.inc("generated", placement.real_rows)
            self.metrics.inc("scheduled_rows", placement.total_rows)
            self.metrics.inc("padded", placement.padded)
            for w, hs in zip(placement.windows, host_stats):
                h = w.host
                self.metrics.inc("host.rows", w.real, host=h)
                self.metrics.inc("host.padded", w.rows - w.real, host=h)
                self.metrics.inc("host.waves", host=h)
                self.metrics.inc("host.row_iters_scheduled",
                                 hs["scheduled"], host=h)
                self.metrics.inc("host.row_iters_active", hs["active"],
                                 host=h)
                self.metrics.inc("row_iters_scheduled", hs["scheduled"])
                self.metrics.inc("row_iters_active", hs["active"])
            if inflight is not None:
                self._retire_placed(st, results, *inflight)
            if self.async_waves:
                inflight = (xs, invs, placement, parts_h, wave)
            else:
                self._retire_placed(st, results, xs, invs, placement,
                                    parts_h, wave)
        if inflight is not None:
            self._retire_placed(st, results, *inflight)

    def _handle_host_loss(self, grp: _ShardedGroup, st: "_DrainState",
                          parts_h, err: HostLostError):
        """Elastic membership: mark the lost host failed (survivors
        re-quota on the next wave), put the aborted wave's rows back on
        their queues (front, pack order), and migrate the dead host's
        admitted REQUESTS — not its padded rows — onto survivors' ingress
        queues by identity routing over the live set.  Migration covers
        EVERY sharded group, not just the one mid-wave: grouped-mode
        drains hold one ``_ShardedGroup`` per (guidance, steps), and a
        request parked on the dead host's queue of a not-yet-drained
        group would otherwise be unreachable (its window quota is 0
        forever) while still counting as available — losing the request
        and livelocking the drain loop."""
        # un-take the whole aborted wave FIRST: restore each pending's
        # ``taken`` and put exhausted (popped) pendings back at the queue
        # front in pack order — identical rows will repack under the new
        # quotas.  Doing this before any ``mark_failed`` keeps the queues
        # whole even when the last survivor dies here (the concurrent
        # dispatch can lose SEVERAL hosts in one wave, carried on
        # ``err.also``) and ``AllHostsLostError`` aborts the drain.
        for hq, parts in zip(grp.queues, parts_h):
            for p, t, _ in parts:
                p.taken -= t
            readd = []
            for p, _, _ in parts:
                if not any(q is p for q in readd) and \
                        not any(q is p for q in hq.items):
                    readd.append(p)
            hq.items.extendleft(reversed(readd))
        for loss in (err, *getattr(err, "also", ())):
            dead = loss.host
            # raises AllHostsLostError when no survivor remains
            topo = self.topology.mark_failed(dead)
            self.topology = topo
            self.metrics.inc("fault.host_lost")
            self.metrics.set_gauge("hosts_live", len(topo.live_hosts))
            self.tracer.instant("host.failed", host=dead, wave=loss.wave)
            if self._pool is not None:
                # retire the dead host's worker only — survivors' threads
                # (and the tasks queued on them) are untouched
                self._pool.discard(dead)
            moved = 0
            for g in st.groups.values():
                if not isinstance(g, _ShardedGroup):
                    continue
                dq = g.queues[dead]
                moved += sum(p.rows_left() for p in dq.items)
                for p in list(dq.items):
                    g.push(p, topo.assign(p.req.rid))
                dq.items.clear()
            self.metrics.inc("failover.requeued_rows", moved)

    def _pack_window(self, w, parts, max_steps: int, total_rows: int,
                     wave: int, mixed: bool = False):
        """Pack ONE host's window: concatenate its pending row blocks,
        build per-row meta, pad, and (under compaction) plan the
        window's epoch segments with its activation sort.  Host-LOCAL
        work — it touches only this host's pendings and this window's
        ``_window_geoms`` bucket, so the per-host workers run packs for
        different hosts concurrently.  ``mixed`` is the WAVE-level flag
        (any window of the wave holds a classifier-guided row): mixed
        window segments are distinct executables, so their "auto"
        free-split hits bucket separately.  Returns ``(rows, meta, inv,
        epochs, stats)``."""
        with self.tracer.span("window.pack", wave=wave, **w.span_attrs):
            rows = np.concatenate(
                [p.row_block(t, s, self._null_row if self.ragged else None)
                 for p, t, s in parts])
            # (guidance, steps, rid, absolute row index, mode, clf slot,
            # label) — identical row identity to the single-host packers,
            # so any engine serving these requests draws the same noise
            # streams; the mixed columns are inert for pure-cfg waves
            meta = [(p.req.guidance, p.req.num_steps, p.req.rid,
                     p.req.count - p.fresh + s + i,
                     1.0 if p.req.mode == "clf" else 0.0,
                     (self._clf_slot(p.req.logprob_fn)
                      if p.req.mode == "clf" else 0),
                     p.req.category)
                    for p, t, s in parts for i in range(t)]
            if w.rows > w.real:
                # per-window padding duplicates the window's OWN last
                # row (same identity → a discarded bit-identical copy)
                rows = np.concatenate(
                    [rows,
                     np.repeat(rows[-1:], w.rows - w.real, axis=0)])
                meta += [meta[-1]] * (w.rows - w.real)
            # useful work: each REAL row's own step count, pre-sort
            active = int(sum(m[1] for m in meta[:w.real]))
            steps_w = np.array([m[1] for m in meta], np.int32)
            if self.compaction is not None:
                seg_granule = (self.topology.granules[w.host]
                               if self.mesh is not None else 1)
                geoms = self._window_geoms.setdefault(
                    (w.offset, total_rows, "mixed") if mixed
                    else (w.offset, total_rows), set())
                order, epochs = plan_epochs(
                    steps_w, max_steps, compaction=self.compaction,
                    granule=seg_granule, geoms=geoms,
                    compile_cost=self.compaction_compile_cost)
                rows = rows[order]
                meta = [meta[i] for i in order]
                inv = np.empty_like(order)
                inv[order] = np.arange(len(order))
            else:
                # one segment spanning the whole scan: right-aligned
                # rows ride frozen, exactly like the one-shot ragged
                # wave
                epochs, inv = ((w.rows, 0, max_steps),), None
            return rows, meta, inv, epochs, \
                {"active": active,
                 "scheduled": sum(r * (e - b) for r, b, e in epochs)}

    def _dispatch_window(self, w, epochs, ctx, wave: int):
        """Dispatch ONE host window's jitted segment chain — device_put
        through the host submesh shardings, then enqueue every epoch
        segment — WITHOUT fencing: JAX's async dispatch returns as soon
        as the work is enqueued, so back-to-back (or per-host-worker)
        calls overlap host h+1's dispatch with host h's device scan.
        ``_retire_placed`` fences the returned output later."""
        y, row_keys, g, ts, ab_t, ab_prev, jloc, act, B, mx = ctx
        # the host-window dispatch fault site: a fault here models the
        # host dying with its window undispatched — the drain's failover
        # path requeues the wave and carries on
        self._check_fault("window", host=w.host, wave=wave)
        lo = w.offset
        sh = self._window_shardings(w.host)
        x = jnp.zeros((0, self.image_size, self.image_size,
                       self.channels))
        prev = 0
        with self.tracer.span("window.dispatch", wave=wave,
                              segments=len(epochs), **w.span_attrs):
            for rows, begin, end in epochs:
                # full executable key: a window segment specializes on
                # (wave width, carried, live, iterations) — NOT the
                # window offset, which is a traced operand, so equal-
                # quota hosts share one executable per segment geometry.
                # Mixed waves additionally key on the ensemble tuple.
                if mx is not None:
                    self._note_shape(("mixed-win", B, prev, rows,
                                      end - begin, len(mx[3])))
                else:
                    self._note_shape(("cfg-win", B, prev, rows,
                                      end - begin))
                if self.compaction is not None:
                    gk = (lo, B, "mixed") if mx is not None else (lo, B)
                    self._window_geoms[gk].add((prev, rows, end - begin))
                    self.metrics.inc("segments")
                hi = lo + rows
                args = dict(y=y[lo:hi], rk=row_keys[lo:hi], g=g,
                            ts=ts[lo:hi, begin:end],
                            jloc=jloc[lo:hi, begin:end],
                            ab_t=ab_t[:, begin:end],
                            ab_prev=ab_prev[:, begin:end],
                            act=act[:, begin:end])
                if mx is not None:
                    args.update(mode=mx[0], cids=mx[1][lo:hi],
                                labels=mx[2][lo:hi])
                if sh is not None:
                    # the row-window layout (wave_window_specs):
                    # window rows shard over the host submesh's data
                    # axes, the wave-resident tables replicate onto
                    # that submesh
                    args = {k: jax.device_put(v, sh[k])
                            for k, v in args.items()}
                with self.tracer.span("segment.dispatch", host=w.host,
                                      rows=rows, begin=begin, end=end):
                    if mx is not None:
                        x = _window_segment_mixed(
                            self.dm_params, self.dc, x, args["y"],
                            args["rk"], args["g"], args["ts"],
                            args["jloc"], args["ab_t"],
                            args["ab_prev"], args["act"],
                            mode=args["mode"], clf_ids=args["cids"],
                            labels=args["labels"], clf_fns=mx[3],
                            row_offset=lo,
                            image_size=self.image_size,
                            channels=self.channels, eta=self.eta,
                            use_pallas=self.use_pallas)
                    else:
                        x = _window_segment(
                            self.dm_params, self.dc, x, args["y"],
                            args["rk"], args["g"], args["ts"],
                            args["jloc"], args["ab_t"],
                            args["ab_prev"], args["act"],
                            row_offset=lo,
                            image_size=self.image_size,
                            channels=self.channels, eta=self.eta,
                            use_pallas=self.use_pallas)
                prev = rows
        if self._sync_hook is not None:
            self._sync_hook("dispatch", w.host, wave)
        return jnp.clip(x, -1.0, 1.0)

    def _sample_wave_placed(self, parts_h, placement: WavePlacement, key,
                            max_steps: int, wave: int = -1):
        """Sample one placed wave, window-concurrently.

        Three phases.  PACK: each host's window packs on that host's
        worker (``_pack_window`` — rows, meta, per-window padding,
        activation-sorted when compaction is on so its epoch segments
        stay contiguous prefixes), overlapping other hosts' packs and
        device scans.  ASSEMBLE (sequential, cheap): splice the windows
        into ONE wave-resident set of per-row tables (``ragged_tables``
        over the whole wave) in window order.  DISPATCH: every window's
        jitted segment chain is enqueued — on its host's worker when the
        pool is live, back-to-back otherwise — before ANY fence, each
        reading the wave table at ``row_offset = window.offset``.
        Worker errors marshal back deterministically (``_collect``).

        Returns per-window device outputs (still in sorted order), the
        per-window inverse permutations, and per-window scheduled/active
        row-iteration counts.  Bit-identical with the pool on or off:
        packing/dispatch order never keys noise — row identity does."""
        pool = self._ensure_pool()
        wins = placement.windows
        # WAVE-level mixedness: one classifier-guided row anywhere makes
        # every window of the wave dispatch the mixed executable (windows
        # share the wave-resident tables; a mixed executable on pure-cfg
        # rows is the identical arithmetic bit-for-bit)
        mixed = any(p.req.mode == "clf"
                    for parts in parts_h for p, _, _ in parts)
        if pool is not None and all(w.host in pool.hosts for w in wins):
            packed = self._collect(
                [pool.submit(w.host, self._pack_window, w, parts_h[w.host],
                             max_steps, placement.total_rows, wave, mixed)
                 for w in wins])
        else:
            packed = [self._pack_window(w, parts_h[w.host], max_steps,
                                        placement.total_rows, wave, mixed)
                      for w in wins]
        win_rows = [p[0] for p in packed]
        win_meta = [p[1] for p in packed]
        win_inv = [p[2] for p in packed]
        win_plans = [p[3] for p in packed]
        host_stats = [p[4] for p in packed]
        meta_wave = [m for ms in win_meta for m in ms]
        cond = np.concatenate(win_rows)
        g = jnp.asarray([m[0] for m in meta_wave], jnp.float32)
        steps = np.array([m[1] for m in meta_wave], np.int32)
        row_keys = self._row_keys(meta_wave, key)
        ts, ab_t, ab_prev, jloc = ragged_tables(self.sched, steps, max_steps)
        act = jloc >= 0
        y = jnp.asarray(cond)
        # the mixed operands ride the ctx as one optional slot: mode is a
        # wave-resident table (read through row_offset like ab_t), the
        # classifier ids/labels are sliced per window like the cond rows
        mx = None
        if mixed:
            mx = (jnp.asarray([m[4] for m in meta_wave], jnp.float32),
                  np.array([m[5] for m in meta_wave], np.int32),
                  np.array([m[6] for m in meta_wave], np.int32),
                  tuple(self._clf_fns))
        ctx = (y, row_keys, g, ts, ab_t, ab_prev, jloc, act,
               placement.total_rows, mx)
        if pool is not None and all(w.host in pool.hosts for w in wins):
            xs = self._collect(
                [pool.submit(w.host, self._dispatch_window, w, epochs,
                             ctx, wave)
                 for w, epochs in zip(wins, win_plans)])
        else:
            xs = [self._dispatch_window(w, epochs, ctx, wave)
                  for w, epochs in zip(wins, win_plans)]
        return xs, win_inv, host_stats

    def _window_shardings(self, host: int) -> Optional[dict]:
        """Per-argument shardings for host ``host``'s window segments —
        the ``sharding/rules.py::wave_window_specs`` layout instantiated
        on the host's compute mesh (``HostTopology.host_mesh``), cached
        per host.  None for a simulated (mesh-less) topology: windows run
        wherever the local devices are."""
        if host in self._host_shardings:
            return self._host_shardings[host]
        sub = self.topology.host_mesh(host)
        sh = None
        if sub is not None:
            from repro.launch.mesh import mesh_axes
            from repro.sharding.rules import wave_window_specs
            specs = wave_window_specs(mesh_axes(sub))
            sh = {"y": NamedSharding(sub, specs["cond"]),
                  "rk": NamedSharding(sub, specs["row_keys"]),
                  "ts": NamedSharding(sub, specs["cond"]),
                  "jloc": NamedSharding(sub, specs["cond"]),
                  "g": NamedSharding(sub, specs["guidance"]),
                  "ab_t": NamedSharding(sub, specs["scalar_table"]),
                  "ab_prev": NamedSharding(sub, specs["scalar_table"]),
                  "act": NamedSharding(sub, specs["scalar_table"]),
                  "mode": NamedSharding(sub, specs["mode"]),
                  "cids": NamedSharding(sub, specs["clf_ids"]),
                  "labels": NamedSharding(sub, specs["labels"])}
        self._host_shardings[host] = sh
        return sh

    def _fence_window(self, w, x, wave: int):
        """Fence ONE window's device output.  On a per-host worker the
        ``device.scan`` span measures that host's own device time — not
        another host's serialized wait, which is what the old in-order
        fence loop silently recorded for every window after the first."""
        with self.tracer.span("device.scan", host=w.host, rows=w.rows):
            if self._sync_hook is not None:
                self._sync_hook("fence", w.host, wave)
            self._fence(x, host=w.host, wave=wave)

    def _retire_placed(self, st: "_DrainState", results, xs, invs,
                       placement: WavePlacement, parts_h, wave: int = -1):
        """Fence every window — on the per-host workers when the pool is
        live, so windows fence as they complete and a straggling host
        never serializes the others — then unsort compacted windows back
        to pack order, strip per-window padding, and scatter rows to
        requests in window order (delivery stays deterministic)."""
        pool = self._ensure_pool()
        wins = placement.windows
        if pool is not None and all(w.host in pool.hosts for w in wins):
            self._collect([pool.submit(w.host, self._fence_window, w, x,
                                       wave)
                           for w, x in zip(wins, xs)])
        else:
            for w, x in zip(wins, xs):
                self._fence_window(w, x, wave)
        for w, x, inv in zip(placement.windows, xs, invs):
            arr = np.asarray(x)
            if inv is not None:
                arr = arr[inv]
            outs = arr[:w.real]
            off = 0
            for p, t, _ in parts_h[w.host]:
                p.chunks.append(outs[off:off + t])
                off += t
                if p.done_rows() == p.fresh:
                    self._finalize(st, p, results)

    def _retire(self, st: "_DrainState", results, x, parts, n_real,
                wave: int = -1):
        """Fence on the wave's device computation, scatter rows back to
        their requests, finalize any request whose rows are complete."""
        with self.tracer.span("device.scan", host=0, rows=int(x.shape[0])):
            self._fence(x, host=0, wave=wave)
        outs = np.asarray(x)[:n_real]
        off = 0
        for p, t, _ in parts:
            p.chunks.append(outs[off:off + t])
            off += t
            if p.done_rows() == p.fresh:
                self._finalize(st, p, results)

    def _finalize(self, st: "_DrainState", p: _Pending, results):
        self.tracer.stamp(p.req.rid, "retire")
        new = (np.concatenate(p.chunks) if p.chunks else
               np.zeros((0, self.image_size, self.image_size, self.channels),
                        np.float32))
        r = p.req
        if r.cache_key is not None:
            have = self._cache.get(r.cache_key)
            merged = new if have is None else np.concatenate([have, new])
            self._cache[r.cache_key] = merged
            # these rows moved from planned to cached — leaving them in
            # ``planned`` would double-count coverage for a same-key
            # request streamed in later this drain
            left = st.planned.get(r.cache_key, 0) - p.fresh
            st.planned[r.cache_key] = max(left, 0)
            if self.store is not None:
                self.store.put(r.cache_key, merged)
            st.deliver(results, r.rid, merged[:r.count].copy())
            self._serve_waiters(st, results)
        else:
            st.deliver(results, r.rid, new)

    def _serve_waiters(self, st: "_DrainState", results):
        still = []
        for r in st.waiters:
            cached = self._cache.get(r.cache_key)
            if cached is not None and len(cached) >= r.count:
                st.deliver(results, r.rid, cached[:r.count].copy())
            else:
                still.append(r)
        st.waiters = still


class _DrainState:
    """Book-keeping for one drain: live group queues, per-key rows already
    planned (cache top-up accounting), requests waiting on rows another
    request is generating, and the wave counter keying ``fold_in``."""

    def __init__(self):
        self.groups: dict[tuple, _GroupQueue] = {}
        self.planned: dict[tuple, int] = {}
        self.waiters: list[SynthesisRequest] = []
        self.admitted: set[int] = set()
        self.wave_i = 0
        self.started = False          # True once initial admission is done
        self.on_result = None         # this drain's streaming delivery hook
        self.on_error = None          # typed-failure delivery hook
        self.failed = {}              # rid -> RequestFailedError this drain
        self.tracer = None            # set by the engine at drain start

    def deliver(self, results: dict, rid: int, rows):
        if self.tracer is not None:
            self.tracer.stamp(rid, "deliver")
        results[rid] = rows
        if self.on_result is not None:
            self.on_result(rid, rows)
