"""Serving steps: prefill (batch context ingest) and decode (one token
against the KV cache / recurrent state)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.moe import Parallel
from repro.models.transformer import decode_step, forward


def make_prefill_step(cfg: ModelConfig, par: Parallel = Parallel()):
    """prefill_step(params, batch) -> (last_logits, caches)."""

    def prefill_step(params, batch):
        logits, _, caches = forward(params, cfg, batch, par, mode="prefill")
        return logits[:, -1:, :], caches

    return prefill_step


def make_serve_step(cfg: ModelConfig, par: Parallel = Parallel(), *,
                    greedy: bool = True):
    """serve_step(params, tokens (B,1), caches, pos) ->
    (next_token (B,1), logits, caches)."""

    def serve_step(params, tokens, caches, pos):
        logits, caches = decode_step(params, cfg, tokens, caches, pos, par)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, caches

    return serve_step
