"""Batched serving engine: wave-scheduled batching over the KV-cache
runtime.

The paper's server synthesises data in large equal-length batches; this
engine is the generic serving substrate underneath: requests are grouped
into WAVES of equal prompt length, each wave prefills as one batch and
decodes in lockstep (one fused decode step per tick for the whole pool),
finishing when every member hits its token budget / EOS.

Lockstep waves keep the single-position decode step exact (a per-slot
position would need per-row cache write masking — noted as the
ragged-batching extension).  CPU-sized by default; the step functions are
identical to what the multi-pod dry-run lowers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.moe import Parallel
from repro.models.transformer import forward
from repro.models.attention import KVCache
from repro.obs.metrics import MetricsRegistry
from repro.serve.steps import make_serve_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new: int = 32
    eos: Optional[int] = None
    out: list = field(default_factory=list)


class ServeEngine:
    """Wave-based batched generation."""

    _STAT_KEYS = ("waves", "prefilled", "decoded")

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 256,
                 par: Parallel = Parallel(),
                 metrics: MetricsRegistry | None = None):
        assert cfg.supports_decode, f"{cfg.name} is encoder-only"
        self.cfg, self.params, self.par = cfg, params, par
        self.max_len = max_len
        self._decode = jax.jit(make_serve_step(cfg, par))
        self._queue: list[Request] = []
        self._next_rid = 0
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    @property
    def stats(self) -> dict:
        """Legacy dict view over the metrics registry (same keys the
        pre-registry engine kept by hand)."""
        return {k: self.metrics.get(k) for k in self._STAT_KEYS}

    def submit(self, prompt, max_new: int = 32, eos: int | None = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, np.asarray(prompt, np.int32),
                                   max_new, eos))
        return rid

    def run(self) -> dict[int, list[int]]:
        """Drain the queue.  Returns rid -> generated token ids."""
        results: dict[int, list[int]] = {}
        while self._queue:
            # wave = all queued requests sharing the front prompt length
            L = len(self._queue[0].prompt)
            wave = [r for r in self._queue if len(r.prompt) == L]
            self._queue = [r for r in self._queue if len(r.prompt) != L]
            self._run_wave(wave, results)
        return results

    # -- internals --------------------------------------------------------
    def _pad_caches(self, caches, L):
        def pad_leaf(c):
            if isinstance(c, KVCache):
                pad = self.max_len - c.k.shape[2]
                return KVCache(
                    jnp.pad(c.k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
                    jnp.pad(c.v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))))
            return c
        return {k: pad_leaf(v) for k, v in caches.items()}

    def _run_wave(self, wave, results):
        L = len(wave[0].prompt)
        budget = max(r.max_new for r in wave)
        assert L + budget <= self.max_len, "wave exceeds engine max_len"
        toks = jnp.asarray(np.stack([r.prompt for r in wave]))
        logits, _, caches = forward(self.params, self.cfg, {"tokens": toks},
                                    self.par, mode="prefill")
        caches = self._pad_caches(caches, L)
        self.metrics.inc("waves")
        self.metrics.inc("prefilled", len(wave))
        cur = jnp.argmax(logits[:, -1, :self.cfg.vocab_size], -1)[:, None]
        cur = cur.astype(jnp.int32)
        done = [False] * len(wave)
        for r, t in zip(wave, np.asarray(cur[:, 0])):
            r.out.append(int(t))
        for i in range(budget - 1):
            cur, _, caches = self._decode(self.params, cur, caches,
                                          jnp.int32(L + i))
            self.metrics.inc("decoded", len(wave))
            toks_np = np.asarray(cur[:, 0]) % self.cfg.vocab_size
            for j, (r, t) in enumerate(zip(wave, toks_np)):
                if done[j]:
                    continue
                r.out.append(int(t))
                if len(r.out) >= r.max_new or (r.eos is not None
                                               and int(t) == r.eos):
                    done[j] = True
                    results[r.rid] = r.out
            if all(done):
                break
        for j, r in enumerate(wave):
            if not done[j]:
                results[r.rid] = r.out
