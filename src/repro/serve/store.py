"""Persistent content-addressed D_syn store.

Spills the SynthesisEngine's (encoding-hash, guidance, steps) output
cache to disk so repeated ``run_oscar`` / ``run_feddisc`` / benchmark
invocations skip synthesis entirely ACROSS PROCESSES — a cold process
pointed at a warm store serves the whole workload with zero sampler
calls and bit-identical rows.

Layout mirrors ``checkpoint/io.py`` (plain npz + JSON manifest,
inspectable with numpy alone)::

    <root>/manifest.json            {"version": 1, "entries": {slug: {...}}}
    <root>/shards/<slug>.npz        {"rows": (count, H, W, C)}

The slug is the CONTENT ADDRESS: sha1 over the cache key — itself the
sha1 of the uploaded encoding bytes plus the guidance scale and step
count — so two stores built from the same uploads share shard names and
a shard can never be served to the wrong request.  Every manifest entry
records count/shape/dtype and is validated against the shard on load;
``put`` buffers in memory and ``flush`` (called by the engine at the end
of every drain) writes dirty shards and rewrites the manifest via a
temp-file rename.

The store does NOT key on the diffusion model's parameters — callers
serving multiple DMs must use one store root per model (see
``core/experiment.py``, which keys the store directory by the DM cache
tag).

DEGRADED OPERATION (``serve/faults.py``): the store is a CACHE, so no
I/O problem is ever worth failing a request over.  Transient read/write
errors retry under the bound ``RetryPolicy``; a shard that stays
unreadable is a miss (re-synthesize); a CORRUPT shard — undecodable
npz, wrong recorded key, structural mismatch vs its manifest entry — is
QUARANTINED: its manifest entry is dropped (rewritten first, same
crash-safe ordering as ``evict``), the file moves to
``<root>/quarantine/`` for post-mortem, and the key misses so the
engine regenerates and the next flush heals the manifest.
``store.quarantined`` / ``store.write_failures`` / ``retry.*`` counters
land on the bound registry.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from pathlib import Path
from typing import Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve.faults import (FaultInjector, RetryPolicy,
                                TransientFaultError)

_VERSION = 1


def _slug(cache_key: tuple) -> str:
    enc_hash, guidance, steps = cache_key
    # repr() is round-trip exact — two distinct guidance floats can never
    # share a slug (get() additionally validates the recorded key)
    raw = f"{enc_hash}|g={float(guidance)!r}|s={int(steps)}"
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


class SynthesisStore:
    """On-disk companion to the engine's in-memory output cache."""

    def __init__(self, root: str | Path):
        # standalone defaults; ``bind`` swaps in the engine's shared
        # registry/tracer at drain start so store I/O lands on the same
        # timeline and metrics dump as the waves it feeds
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(enabled=False)
        self.faults: Optional[FaultInjector] = None
        self.retry = RetryPolicy()
        self.root = Path(root)
        self._shards = self.root / "shards"
        self._rows: dict[str, np.ndarray] = {}      # loaded / pending shards
        self._dirty: set[str] = set()
        self._evicted: set[str] = set()     # tombstones: never merged back
        self._manifest: dict = {"version": _VERSION, "entries": {}}
        mpath = self.root / "manifest.json"
        if mpath.exists():
            self._manifest = json.loads(mpath.read_text())
            if self._manifest.get("version") != _VERSION:
                raise ValueError(
                    f"store {self.root}: unsupported manifest version "
                    f"{self._manifest.get('version')!r}")
        # LRU clock: monotone per-entry access stamps ("lru", absent on
        # pre-eviction manifests → treated as oldest); persisted whenever
        # the manifest is rewritten, so recency survives the process
        self._clock = 1 + max((e.get("lru", 0)
                               for e in self._manifest["entries"].values()),
                              default=0)

    def bind(self, metrics: MetricsRegistry, tracer: Tracer,
             faults: FaultInjector | None = None,
             retry: RetryPolicy | None = None):
        """Adopt the engine's shared metrics registry, tracer, and fault
        policy (injector + retry), so store I/O recovers under the same
        knobs as the drain that drives it."""
        self.metrics = metrics
        self.tracer = tracer
        if faults is not None:
            self.faults = faults
        if retry is not None:
            self.retry = retry

    def _check_fault(self, site: str):
        if self.faults is None:
            return
        try:
            self.faults.check(site)
        except Exception:
            self.metrics.inc("fault.injected", site=site)
            raise

    def _touch(self, slug: str):
        ent = self._manifest["entries"].get(slug)
        if ent is not None:
            ent["lru"] = self._clock
            self._clock += 1

    # -- reads ------------------------------------------------------------
    def get(self, cache_key: tuple) -> Optional[np.ndarray]:
        """All rows stored under ``cache_key``, or None.  Lazy: the shard
        is read (and validated against its manifest entry) on first use.

        A shard SHORTER than its manifest entry — a lost race between
        concurrent same-key flushes — is treated as a miss, not an error:
        the caller re-synthesizes and the next flush heals the entry
        ('costs a re-synthesis, never a wrong result').  A shard LONGER
        than its entry (crash between shard and manifest renames) serves
        the recorded prefix; shards are append-only so the prefix is
        exact.  CORRUPTION — a wrong recorded key, an undecodable npz, a
        row shape/dtype mismatch — never raises: the shard is quarantined
        (manifest healed, file moved to ``quarantine/``) and the key
        misses, so the engine regenerates it.  Transient I/O retries
        under the bound policy; a shard that stays unreadable is a plain
        miss (the file may be fine — don't quarantine it)."""
        s = _slug(cache_key)
        if s in self._rows:
            self._touch(s)
            self.metrics.inc("store.hits")
            return self._rows[s]
        ent = self._manifest["entries"].get(s)
        if ent is None:
            self.metrics.inc("store.misses")
            return None
        enc_hash, guidance, steps = cache_key
        if (ent["key"]["encoding_sha1"] != enc_hash
                or ent["key"]["guidance"] != float(guidance)
                or ent["key"]["steps"] != int(steps)):
            # slugs are content addresses, so a key mismatch means the
            # manifest entry itself is corrupt — never serve it
            self._quarantine(s, "recorded cache key mismatch")
            self.metrics.inc("store.misses")
            return None

        def _read():
            self._check_fault("store.read")
            with np.load(self._shards / f"{s}.npz") as z:
                return z["rows"]

        try:
            t0 = time.perf_counter()
            with self.tracer.span("store.read", track="store", slug=s):
                rows = self.retry.run(_read, metrics=self.metrics,
                                      site="store.read")
            self.metrics.observe("store.read_s", time.perf_counter() - t0)
        except FileNotFoundError:
            # another handle evicted the shard after we read the manifest
            # — a miss, not corruption: re-synthesize and heal
            self.metrics.inc("store.misses")
            return None
        except (TransientFaultError, OSError):
            # unreadable even after retries: miss, but the file may be
            # fine (flaky media) — leave it in place
            self.metrics.inc("store.misses")
            return None
        except Exception as exc:
            # np.load decode failure — a torn or garbage shard file
            self._quarantine(s, f"undecodable shard: {exc!r}")
            self.metrics.inc("store.misses")
            return None
        if (list(rows.shape[1:]) != list(ent["shape"])[1:]
                or str(rows.dtype) != ent["dtype"]):
            self._quarantine(
                s, f"shape {list(rows.shape)}/{ent['shape']} dtype "
                   f"{rows.dtype}/{ent['dtype']} mismatch")
            self.metrics.inc("store.misses")
            return None
        if len(rows) < ent["count"]:
            self.metrics.inc("store.misses")
            return None                     # lost flush race: re-synthesize
        self._rows[s] = rows = rows[:ent["count"]]
        self._touch(s)
        self.metrics.inc("store.hits")
        return rows

    def _quarantine(self, slug: str, reason: str):
        """Contain a corrupt shard: drop its manifest entry and every
        in-memory trace, tombstone it (a concurrent flush must not
        resurrect the entry), rewrite the manifest, and only THEN move
        the file into ``quarantine/`` — the same manifest-before-file
        ordering ``evict`` uses, so a crash mid-quarantine strands at
        worst an orphaned shard file, never a dangling manifest entry.
        A later ``put`` on the key regenerates cleanly (it clears the
        tombstone and heals the manifest)."""
        self._manifest["entries"].pop(slug, None)
        self._rows.pop(slug, None)
        self._dirty.discard(slug)
        self._evicted.add(slug)
        self.metrics.inc("store.quarantined")
        self.tracer.instant("store.quarantine", track="store", slug=slug,
                            reason=reason)
        self._write_manifest()
        src = self._shards / f"{slug}.npz"
        if src.exists():
            qdir = self.root / "quarantine"
            qdir.mkdir(parents=True, exist_ok=True)
            os.replace(src, qdir / f"{slug}.npz")

    def __contains__(self, cache_key: tuple) -> bool:
        return _slug(cache_key) in self._manifest["entries"]

    def __len__(self) -> int:
        return len(self._manifest["entries"])

    # -- writes -----------------------------------------------------------
    def put(self, cache_key: tuple, rows: np.ndarray):
        """Record the full row set for ``cache_key`` (the engine always
        hands the merged cache entry, so a put only ever grows a shard).
        Buffered until ``flush``."""
        s = _slug(cache_key)
        have = self._rows.get(s)
        if have is not None and len(have) > len(rows):
            return                      # never shrink a shard
        self._rows[s] = np.asarray(rows)
        self._dirty.add(s)
        self._evicted.discard(s)            # re-putting resurrects the key
        enc_hash, guidance, steps = cache_key
        self._manifest["entries"][s] = {
            "key": {"encoding_sha1": enc_hash, "guidance": float(guidance),
                    "steps": int(steps)},
            "count": int(len(rows)),
            "shape": [int(d) for d in rows.shape],
            "dtype": str(rows.dtype),
            "file": f"shards/{s}.npz",
        }
        self._touch(s)

    def flush(self):
        """Write dirty shards, then rewrite the manifest.  Both go through
        temp + rename, shards strictly before the manifest, so a crash at
        any point leaves every manifest entry pointing at a shard holding
        at least its recorded rows (``get`` serves the manifest prefix).

        The on-disk manifest is re-read and merged before the rewrite —
        entries another process flushed since we opened the store are
        kept (our own dirty keys win), so concurrent processes sharing a
        root extend rather than erase each other.  The merge is
        best-effort (read-merge-write without a lock): simultaneous
        flushes can still lose the race for non-overlapping keys, which
        costs a re-synthesis, never a wrong result."""
        if not self._dirty:
            return
        self._shards.mkdir(parents=True, exist_ok=True)
        written = set()
        with self.tracer.span("store.flush", track="store",
                              shards=len(self._dirty)):
            for s in sorted(self._dirty):
                # pid-suffixed like the manifest tmp: concurrent flushes
                # must never interleave writes into one tmp and publish a
                # torn npz
                def _write(s=s):
                    self._check_fault("store.write")
                    tmp = self._shards / f"{s}.{os.getpid()}.tmp"
                    with open(tmp, "wb") as f:
                        np.savez(f, rows=self._rows[s])
                    os.replace(tmp, self._shards / f"{s}.npz")

                t0 = time.perf_counter()
                try:
                    with self.tracer.span("store.write", track="store",
                                          slug=s):
                        self.retry.run(_write, metrics=self.metrics,
                                       site="store.write")
                except Exception:
                    # degraded, not fatal: the shard stays dirty (and in
                    # memory) for the next flush; serving continues.  If
                    # its manifest entry lands without the shard, readers
                    # see FileNotFoundError — a miss, never a wrong row.
                    self.metrics.inc("store.write_failures")
                    continue
                written.add(s)
                self.metrics.observe("store.write_s",
                                     time.perf_counter() - t0)
            self._write_manifest()
        self._dirty -= written

    def _write_manifest(self):
        """Merge-then-rewrite via temp + rename.  Entries another process
        flushed since we opened the store are kept (our dirty keys win)
        UNLESS this handle evicted them — tombstones stop a concurrent
        flush from resurrecting a shard whose file we deleted."""
        mpath = self.root / "manifest.json"
        if mpath.exists():
            try:
                disk = json.loads(mpath.read_text()).get("entries", {})
            except (json.JSONDecodeError, OSError):
                disk = {}
            ours = self._manifest["entries"]
            for s, ent in disk.items():
                if s not in self._dirty and s not in ours \
                        and s not in self._evicted:
                    ours[s] = ent
        tmp = self.root / f"manifest.json.{os.getpid()}.tmp"
        tmp.write_text(json.dumps(self._manifest, indent=1))
        os.replace(tmp, mpath)

    # -- eviction ---------------------------------------------------------
    @staticmethod
    def _entry_bytes(ent: dict) -> int:
        return int(np.prod(ent["shape"]) * np.dtype(ent["dtype"]).itemsize)

    def total_bytes(self) -> int:
        """Row bytes recorded in the manifest (uncompressed; the budget's
        accounting unit — stable across npz compression ratios)."""
        return sum(self._entry_bytes(e)
                   for e in self._manifest["entries"].values())

    def evict(self, max_bytes: int) -> list[str]:
        """Evict least-recently-used shards until ``total_bytes() <=
        max_bytes``.  Returns the evicted slugs (empty when under budget).

        Ordering is crash-safe for the manifest invariant ('every entry
        points at a shard holding at least its recorded rows'): entries
        leave the manifest — rewritten via temp + rename — BEFORE their
        shard files are unlinked, so a crash mid-evict strands at worst
        an orphaned shard file, never a dangling manifest entry.  An
        evicted key simply misses and re-synthesizes."""
        entries = self._manifest["entries"]
        total = self.total_bytes()
        if total <= max_bytes:
            return []
        # publish pending shards first: the manifest rewrite below must
        # never expose a dirty entry whose shard is not on disk yet
        self.flush()
        victims = []
        for s, ent in sorted(entries.items(),
                             key=lambda kv: kv[1].get("lru", 0)):
            if total <= max_bytes:
                break
            total -= self._entry_bytes(ent)
            victims.append(s)
        self.metrics.inc("store.evictions", len(victims))
        for s in victims:
            entries.pop(s)
            self._rows.pop(s, None)
            self._dirty.discard(s)
            self._evicted.add(s)
        self._write_manifest()
        for s in victims:
            try:
                (self._shards / f"{s}.npz").unlink()
            except FileNotFoundError:
                pass                    # never flushed, or already gone
        return victims
