from repro.serve.steps import make_prefill_step, make_serve_step
from repro.serve.synthesis import SynthesisEngine, SynthesisRequest
