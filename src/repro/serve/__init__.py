from repro.obs import MetricsRegistry, Tracer, write_trace
from repro.serve.faults import (AllHostsLostError, FaultInjector,
                                HostLostError, InjectedFaultError,
                                RequestFailedError, RetryPolicy,
                                SynthesisError, TransientFaultError,
                                UnservedRequestError, is_transient)
from repro.serve.service import SynthesisFuture, SynthesisService
from repro.serve.steps import make_prefill_step, make_serve_step
from repro.serve.store import SynthesisStore
from repro.serve.synthesis import SynthesisEngine, SynthesisRequest
from repro.serve.topology import HostTopology, HostWindow, WavePlacement
