from repro.serve.steps import make_prefill_step, make_serve_step
