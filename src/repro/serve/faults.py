"""Fault injection, retry policy, and the typed serving-error contract.

The paper motivates one-shot FL by client dropout and stragglers (§I);
at serving scale the same failure modes hit the SERVER: a host dies
mid-drain, a device scan hiccups, a store shard goes unreadable.  This
module is the fault-tolerance substrate the rest of ``serve/`` builds
on, in three pieces:

* ``SynthesisError`` hierarchy — every way a request can fail resolves
  to a TYPED error: transient faults (retryable under policy), a lost
  host (handled by failover, never surfaced per-request), and the
  per-request terminal errors (``RequestFailedError``,
  ``UnservedRequestError``) that ``SynthesisFuture`` delivers.

* ``FaultInjector`` — deterministic fault injection for tests, CI
  gates, and chaos drills.  Faults fire at named SITES inside the
  serving stack (``window`` = host-window dispatch, ``scan`` = device
  scan fence, ``store.read``/``store.write`` = shard I/O), triggered
  either by an explicit (site, host, wave) schedule (each entry fires
  once, so retries make progress) or by a seeded per-check probability.
  No wall-clock and no global RNG — the same injectable-clock
  discipline as ``obs.Tracer``, so a fault schedule is perfectly
  reproducible.

* ``RetryPolicy`` — bounded attempts with exponential backoff on an
  INJECTABLE sleep (tests pass a recording stub; nothing in the policy
  reads a clock), plus transient-vs-permanent classification: transient
  errors burn an attempt, permanent errors raise immediately.

The load-bearing property downstream: row noise is keyed by request
identity (``fold_in(drain_key, rid)``), so every recovery action here —
requeue to a survivor, regenerate a quarantined shard, retry a drain —
reproduces bit-identical rows.  Fault tolerance never resamples.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "SynthesisError", "TransientFaultError", "InjectedFaultError",
    "HostLostError", "AllHostsLostError", "RequestFailedError",
    "UnservedRequestError", "is_transient", "FaultInjector", "RetryPolicy",
]


class SynthesisError(RuntimeError):
    """Base of every typed serving error.  Anything a drain or a future
    raises on purpose is a ``SynthesisError``; a bare exception escaping
    the serving stack is a bug, not a contract."""


class TransientFaultError(SynthesisError):
    """A fault worth retrying: the operation may succeed if re-run
    (flaky I/O, injected transient).  ``RetryPolicy`` burns attempts on
    these and raises everything else immediately."""


class InjectedFaultError(TransientFaultError):
    """A fault raised by ``FaultInjector`` at a non-fatal site."""

    def __init__(self, site: str, host: int = -1, wave: int = -1):
        super().__init__(f"injected fault at site={site!r} "
                         f"host={host} wave={wave}")
        self.site, self.host, self.wave = site, host, wave


class HostLostError(SynthesisError):
    """Host ``host`` died dispatching wave ``wave``.  Not retryable and
    not per-request: the drain handles it by marking the host failed and
    requeueing its requests onto survivors (``_drain_group_placed``)."""

    def __init__(self, host: int, wave: int = -1):
        super().__init__(f"host {host} lost dispatching wave {wave}")
        self.host, self.wave = host, wave


class AllHostsLostError(SynthesisError):
    """Every host in the topology has failed — there is no survivor to
    requeue onto, so the drain cannot make progress."""


class RequestFailedError(SynthesisError):
    """Request ``rid`` failed PERMANENTLY this drain (its group's
    sampler raised a non-transient error).  Delivered onto the affected
    ``SynthesisFuture`` only; ``__cause__`` carries the original
    exception."""

    def __init__(self, message: str, *, rid: int):
        super().__init__(message)
        self.rid = rid


class UnservedRequestError(SynthesisError):
    """A future's drain completed without producing rows or a failure
    for this request — the engine was drained without the service's
    delivery hook.  Re-submit through the service."""


def is_transient(exc: BaseException) -> bool:
    """Default transient-vs-permanent classifier: injected/transient
    faults and OS-level I/O errors (except a plain missing file, which
    is a deterministic cache miss) are worth retrying."""
    if isinstance(exc, TransientFaultError):
        return True
    if isinstance(exc, FileNotFoundError):
        return False
    return isinstance(exc, OSError)


#: Sites the serving stack checks.  ``window`` faults model a lost host
#: (fatal for the host, handled by failover); the rest are transient.
FAULT_SITES = ("window", "scan", "store.read", "store.write")


class FaultInjector:
    """Deterministic fault injection at named serving sites.

    Two trigger modes, composable:

    * ``schedule`` — iterable of ``(site, host, wave)`` triples.
      ``host``/``wave`` may be ``None`` (wildcard).  Each entry fires
      exactly ONCE (first matching check), so a retried operation makes
      progress and a failover's replacement wave is not re-killed by the
      same entry.
    * ``p``/``seed`` — every check draws from a PRIVATE stream keyed by
      ``(seed, site, host, wave, occurrence)`` and fires with
      probability ``p``.  No global RNG, no wall-clock, and the draw
      depends only on WHAT is checked, never on the order checks arrive
      — so whether any given check WOULD fire is reproducible even when
      the engine's per-host drain workers hit sites concurrently in
      scheduler-dependent order.

    ``max_faults`` caps total fires across both modes.  The cap is the
    one arrival-ordered piece of p-mode: slots are claimed first-come,
    so under concurrent workers WHICH candidate fault wins a scarce
    slot can vary with thread interleaving (the served bytes are
    bit-identical either way — failover requeues, never resamples).
    Sequential drains (``workers=False``) reproduce the full ``fired``
    sequence exactly.  ``check`` raises
    ``HostLostError`` for the ``window`` site and ``InjectedFaultError``
    (transient) for every other site; ``fired`` records what actually
    fired, in order.

    ``check`` is THREAD-SAFE (one internal lock over the schedule, the
    per-key occurrence counts, and ``fired``): fault sites fire inside
    per-host workers once drains are concurrent, and a torn
    ``del self._schedule[i]`` would double-fire a one-shot entry.
    """

    def __init__(self, schedule=(), *, p: float = 0.0, seed: int = 0,
                 max_faults: int | None = None):
        norm = []
        for entry in schedule:
            site, host, wave = entry
            if site not in FAULT_SITES:
                raise ValueError(f"unknown fault site {site!r}: "
                                 f"sites are {FAULT_SITES}")
            norm.append([site, host, wave])
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"fault probability p={p} must be in [0, 1]")
        self._schedule = norm            # entries removed as they fire
        self.p = float(p)
        self._seed = int(seed)
        self._counts: dict[tuple, int] = {}   # (site,host,wave) -> checks
        self._lock = threading.Lock()
        self.max_faults = max_faults
        self.fired: list = []            # (site, host, wave) in fire order

    def _capped(self) -> bool:
        return self.max_faults is not None and \
            len(self.fired) >= self.max_faults

    def _draw(self, site: str, host: int, wave: int) -> float:
        """One uniform draw keyed by the CHECK's identity (plus how many
        times this exact site/host/wave was checked before — retries see
        fresh draws), not by arrival order."""
        key = (site, int(host), int(wave))
        n = self._counts.get(key, 0)
        self._counts[key] = n + 1
        seq = np.random.SeedSequence(
            [self._seed, FAULT_SITES.index(site),
             int(host) + 2, int(wave) + 2, n])
        return float(np.random.default_rng(seq).random())

    def check(self, site: str, *, host: int = -1, wave: int = -1) -> None:
        """Raise if a fault is due at this site, else return.  Called by
        the engine/store at each injectable site; a no-op (beyond one
        schedule scan / RNG draw) when nothing matches."""
        with self._lock:
            due = False
            if not self._capped():
                for i, (s, h, w) in enumerate(self._schedule):
                    if s == site and (h is None or h == host) \
                            and (w is None or w == wave):
                        del self._schedule[i]
                        due = True
                        break
                if not due and self.p > 0.0 and \
                        self._draw(site, host, wave) < self.p:
                    due = True
            if not due:
                return
            self.fired.append((site, host, wave))
        if site == "window":
            raise HostLostError(host, wave)
        raise InjectedFaultError(site, host, wave)

    @property
    def pending(self) -> int:
        """Scheduled entries that have not fired yet."""
        return len(self._schedule)


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff on an injectable sleep.

    ``max_attempts`` counts the first try; backoff before retry ``i``
    (0-based) is ``min(base_delay * multiplier**i, max_delay)`` seconds,
    delivered through ``sleep`` (default ``time.sleep``; tests inject a
    recorder — the policy itself never reads a clock).  ``run`` retries
    only errors the classifier calls transient; permanent errors and
    exhausted retries re-raise the original exception.
    """
    max_attempts: int = 3
    base_delay: float = 0.001
    multiplier: float = 2.0
    max_delay: float = 0.1
    sleep: object = field(default=time.sleep, compare=False, repr=False)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts={self.max_attempts} must be >= 1")
        if self.base_delay < 0 or self.max_delay < 0 or self.multiplier < 1:
            raise ValueError("backoff: need base_delay/max_delay >= 0 and "
                             "multiplier >= 1")

    def delay(self, retry: int) -> float:
        """Backoff before 0-based retry number ``retry``."""
        return min(self.base_delay * self.multiplier ** retry, self.max_delay)

    def run(self, fn, *, classify=is_transient, metrics=None,
            site: str = "op"):
        """Call ``fn`` until it succeeds, a permanent error raises, or
        attempts are exhausted.  ``metrics`` (a ``MetricsRegistry``)
        gets ``retry.attempts``/``retry.exhausted`` counters and a
        ``retry.backoff_s`` histogram, labelled by ``site``."""
        for attempt in range(self.max_attempts):
            try:
                return fn()
            except Exception as exc:
                if not classify(exc):
                    raise
                if attempt + 1 >= self.max_attempts:
                    if metrics is not None:
                        metrics.inc("retry.exhausted", site=site)
                    raise
                d = self.delay(attempt)
                if metrics is not None:
                    metrics.inc("retry.attempts", site=site)
                    metrics.observe("retry.backoff_s", d, site=site)
                self.sleep(d)
