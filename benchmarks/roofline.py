"""§Roofline report: per (arch × shape × mesh) compute/memory/collective
terms from the dry-run compile cache (benchmarks/results/dryrun*.json),
plus the DENOISER roofline — ``dit_apply`` before vs after Pallas fusion
(``hlo_analysis.denoiser_cost``), the position the fused-denoiser PR
moves.

The dry-run cache is produced by ``PYTHONPATH=src python -m
repro.launch.dryrun --all [--multi-pod]`` (a subprocess because it forces
512 host devices).  This module only aggregates — it never imports
repro.launch.dryrun.  The denoiser section needs no cache: it is the
structural model evaluated at serving shapes.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import RESULTS, print_table, save_result

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load(path: Path | None = None) -> dict:
    path = path or (RESULTS / "dryrun.json")
    if not path.exists():
        return {}
    return json.loads(path.read_text())


def rows_from(data: dict, pod: str = "1pod", overrides: str = "{}"):
    rows = []
    for key, v in sorted(data.items()):
        arch, shape, p, ov = key.split("|", 3)
        if p != pod or ov != overrides:
            continue
        if v["status"] == "skip":
            rows.append({"arch": arch, "shape": shape, "status": "SKIP",
                         "note": v.get("note", "")[:48]})
            continue
        if v["status"] != "ok":
            rows.append({"arch": arch, "shape": shape, "status": "ERROR"})
            continue
        t = v["roofline"]
        rows.append({
            "arch": arch, "shape": shape, "status": "ok",
            "t_compute_ms": t["t_compute"] * 1e3,
            "t_memory_ms": t["t_memory"] * 1e3,
            "t_collective_ms": t["t_collective"] * 1e3,
            "bottleneck": v["bottleneck"],
            "useful_flops": (v.get("useful_flops_ratio") or 0.0),
        })
    return rows


def run(pod: str = "1pod"):
    data = load()
    rows = rows_from(data, pod)
    if not rows:
        print("(roofline cache empty — run repro.launch.dryrun --all first)")
        return []
    print_table(f"Roofline terms per (arch × shape), {pod} mesh", rows,
                ["arch", "shape", "status", "t_compute_ms", "t_memory_ms",
                 "t_collective_ms", "bottleneck", "useful_flops"])
    ok = [r for r in rows if r["status"] == "ok"]
    n_skip = sum(r["status"] == "SKIP" for r in rows)
    print(f"\n{len(ok)} compiled, {n_skip} documented skips, "
          f"{len(rows)-len(ok)-n_skip} errors")
    save_result(f"roofline_{pod}", rows)
    return rows


def run_denoiser(batch: int = 256):
    """Denoiser roofline before/after fusion at serving shapes.

    ``batch=256`` is one paper-scale classifier-free wave (128 rows,
    cond/uncond stacked).  Shapes: the repo's scaled 16 px DiT (S=17) and
    the same config at the paper's 224 px (S=3137), where the naive
    path's materialised (B, h, S², ) attention dominates HBM traffic.
    """
    from repro.configs.oscar import DiffusionConfig
    from repro.launch.hlo_analysis import (denoiser_cost, dominant_term,
                                           roofline_terms)
    dc = DiffusionConfig()
    rows = []
    for image_size in (16, 224):
        for variant, kw in (("naive", {}), ("fused", dict(fused=True)),
                            ("fused_bf16", dict(fused=True, bf16=True))):
            c = denoiser_cost(dc, batch, image_size, **kw)
            t = roofline_terms(c["flops"], c["bytes"], 0.0)
            rows.append({
                "shape": f"{image_size}px_B{batch}", "variant": variant,
                "gflops": c["flops"] / 1e9, "mbytes": c["bytes"] / 1e6,
                "intensity": c["intensity"],
                "t_compute_us": t["t_compute"] * 1e6,
                "t_memory_us": t["t_memory"] * 1e6,
                "bottleneck": dominant_term(t),
            })
    print_table(f"Denoiser roofline (one dit_apply call, B={batch})", rows,
                ["shape", "variant", "gflops", "mbytes", "intensity",
                 "t_compute_us", "t_memory_us", "bottleneck"])
    save_result("roofline_denoiser", rows)
    return rows


def main():
    run("1pod")
    run("2pod")
    run_denoiser()


if __name__ == "__main__":
    main()
