"""§Roofline report: per (arch × shape × mesh) compute/memory/collective
terms from the dry-run compile cache (benchmarks/results/dryrun*.json).

The cache is produced by ``PYTHONPATH=src python -m repro.launch.dryrun
--all [--multi-pod]`` (a subprocess because it forces 512 host devices).
This module only aggregates — it never imports repro.launch.dryrun.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import RESULTS, print_table, save_result

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load(path: Path | None = None) -> dict:
    path = path or (RESULTS / "dryrun.json")
    if not path.exists():
        return {}
    return json.loads(path.read_text())


def rows_from(data: dict, pod: str = "1pod", overrides: str = "{}"):
    rows = []
    for key, v in sorted(data.items()):
        arch, shape, p, ov = key.split("|", 3)
        if p != pod or ov != overrides:
            continue
        if v["status"] == "skip":
            rows.append({"arch": arch, "shape": shape, "status": "SKIP",
                         "note": v.get("note", "")[:48]})
            continue
        if v["status"] != "ok":
            rows.append({"arch": arch, "shape": shape, "status": "ERROR"})
            continue
        t = v["roofline"]
        rows.append({
            "arch": arch, "shape": shape, "status": "ok",
            "t_compute_ms": t["t_compute"] * 1e3,
            "t_memory_ms": t["t_memory"] * 1e3,
            "t_collective_ms": t["t_collective"] * 1e3,
            "bottleneck": v["bottleneck"],
            "useful_flops": (v.get("useful_flops_ratio") or 0.0),
        })
    return rows


def run(pod: str = "1pod"):
    data = load()
    rows = rows_from(data, pod)
    if not rows:
        print("(roofline cache empty — run repro.launch.dryrun --all first)")
        return []
    print_table(f"Roofline terms per (arch × shape), {pod} mesh", rows,
                ["arch", "shape", "status", "t_compute_ms", "t_memory_ms",
                 "t_collective_ms", "bottleneck", "useful_flops"])
    ok = [r for r in rows if r["status"] == "ok"]
    n_skip = sum(r["status"] == "SKIP" for r in rows)
    print(f"\n{len(ok)} compiled, {n_skip} documented skips, "
          f"{len(rows)-len(ok)-n_skip} errors")
    save_result(f"roofline_{pod}", rows)
    return rows


def main():
    run("1pod")
    run("2pod")


if __name__ == "__main__":
    main()
