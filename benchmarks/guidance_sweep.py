"""Beyond-paper ablation: guidance scale s sweep (the paper fixes s=7.5
for Stable Diffusion; our scaled DM has a different optimum — this bench
documents the transfer and justifies the tuned default)."""
from __future__ import annotations

import jax

from benchmarks.common import get_experiment, print_table, save_result
from repro.core.classifier_train import evaluate_per_domain, fit_global
from repro.core.oscar import client_encodings, synthesize

SCALES = (0.0, 1.0, 2.0, 3.0, 5.0, 7.5)


def run(preset: str = "paper", scales=SCALES, samples: int = 10):
    exp = get_experiment(preset)
    enc, present = client_encodings(exp.fm, exp.data)
    key = jax.random.PRNGKey(3)
    rows, raw = [], {}
    for s in scales:
        sx, sy = synthesize(key, exp.dm_params, exp.ocfg.diffusion, exp.sched,
                            enc, present, samples,
                            image_size=exp.ocfg.data.image_size, guidance=s,
                            service=exp.service)
        gp = fit_global(jax.random.fold_in(key, int(s * 10)),
                        exp.ocfg.classifier, exp.data.num_categories, sx, sy,
                        steps=exp.ocfg.classifier_steps)
        acc = evaluate_per_domain(gp, exp.ocfg.classifier, exp.data)["avg"]
        raw[s] = acc
        rows.append({"guidance_s": s, "avg_acc_pct": acc * 100,
                     "note": "paper default (SD)" if s == 7.5 else
                             ("tuned default" if s == exp.ocfg.diffusion.guidance_scale else "")})
        print(f"  s={s}: {acc*100:.2f}%", flush=True)
    print_table("Guidance-scale transfer (beyond-paper ablation)", rows,
                ["guidance_s", "avg_acc_pct", "note"])
    save_result("guidance_sweep", raw)
    return raw


def main():
    run()


if __name__ == "__main__":
    main()
