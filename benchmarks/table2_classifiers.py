"""Paper Table II: OSCAR's synthetic data consumed by stronger classifier
backbones (ResNet-18/50/101, VGG-16, DenseNet-121, ViT-B16 analogues).
One synthesis pass (10 samples/category, as in the paper) reused by all."""
from __future__ import annotations

import jax

from benchmarks.common import acc_row, get_experiment, print_table, save_result
from repro.core.classifier_train import evaluate_per_domain, fit_global
from repro.core.oscar import client_encodings, synthesize
from repro.models.classifiers import CLASSIFIERS


def run(preset: str = "paper", samples_per_category: int = 10):
    exp = get_experiment(preset)
    enc, present = client_encodings(exp.fm, exp.data)
    key = jax.random.PRNGKey(42)
    syn_x, syn_y = synthesize(key, exp.dm_params, exp.ocfg.diffusion,
                              exp.sched, enc, present, samples_per_category,
                              image_size=exp.ocfg.data.image_size,
                              service=exp.service)
    rows, raw = [], {}
    for name in CLASSIFIERS:
        gp = fit_global(jax.random.fold_in(key, hash(name) % 1000), name,
                        exp.data.num_categories, syn_x, syn_y,
                        steps=exp.ocfg.classifier_steps)
        metrics = evaluate_per_domain(gp, name, exp.data)
        raw[name] = metrics
        rows.append(acc_row(name, metrics, exp.data.num_domains))
        print(f"  {name}: avg {metrics['avg']*100:.2f}%", flush=True)
    cols = ["model"] + [f"client{i+1}" for i in range(exp.data.num_domains)] + ["avg"]
    print_table("Table II — OSCAR with different classifier networks (%)",
                rows, cols)
    save_result("table2_classifiers", raw)
    return raw


def main():
    run()


if __name__ == "__main__":
    main()
