"""Paper Table II: OSCAR's synthetic data consumed by stronger classifier
backbones (ResNet-18/50/101, VGG-16, DenseNet-121, ViT-B16 analogues).
One synthesis pass (10 samples/category, as in the paper) reused by all,
routed through the MERGED ragged scheduler (``ragged=True`` — the one
scheduler serving every guidance mode) and gated by a probe parity
assert."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import acc_row, get_experiment, print_table, save_result
from repro.core.classifier_train import evaluate_per_domain, fit_global
from repro.core.oscar import client_encodings, synthesize
from repro.models.classifiers import CLASSIFIERS


def _assert_merged_parity(exp, enc, present, k, key):
    """The merged-scheduler gate: a probe encoding served from a MIXED
    merged wave (its cfg row block packed next to unconditional rows)
    must be bit-identical to the same request drained alone — fresh
    rid-aligned engines, no cache or store in the loop."""
    from repro.serve.synthesis import SynthesisEngine
    r, c = (int(v) for v in np.argwhere(present)[0])

    def fresh():
        return SynthesisEngine(exp.dm_params, exp.ocfg.diffusion, exp.sched,
                               image_size=exp.ocfg.data.image_size,
                               channels=exp.ocfg.data.channels,
                               ragged=True, cache=False)

    mixed = fresh()
    rid = mixed.submit(enc[r, c], c, k)
    mixed.submit_unconditional(k, category=c)
    out_mixed = mixed.run(key)[rid]
    solo = fresh()
    srid = solo.submit(enc[r, c], c, k)
    out_solo = solo.run(key)[srid]
    assert np.array_equal(out_mixed, out_solo), (
        "merged-scheduler probe diverged: a cfg request packed into a "
        "mixed wave no longer matches its isolated drain bit-for-bit")


def run(preset: str = "paper", samples_per_category: int = 10):
    exp = get_experiment(preset)
    enc, present = client_encodings(exp.fm, exp.data)
    key = jax.random.PRNGKey(42)
    _assert_merged_parity(exp, enc, present, samples_per_category, key)
    syn_x, syn_y = synthesize(key, exp.dm_params, exp.ocfg.diffusion,
                              exp.sched, enc, present, samples_per_category,
                              image_size=exp.ocfg.data.image_size,
                              service=exp.service, ragged=True)
    rows, raw = [], {}
    for name in CLASSIFIERS:
        gp = fit_global(jax.random.fold_in(key, hash(name) % 1000), name,
                        exp.data.num_categories, syn_x, syn_y,
                        steps=exp.ocfg.classifier_steps)
        metrics = evaluate_per_domain(gp, name, exp.data)
        raw[name] = metrics
        rows.append(acc_row(name, metrics, exp.data.num_domains))
        print(f"  {name}: avg {metrics['avg']*100:.2f}%", flush=True)
    cols = ["model"] + [f"client{i+1}" for i in range(exp.data.num_domains)] + ["avg"]
    print_table("Table II — OSCAR with different classifier networks (%)",
                rows, cols)
    save_result("table2_classifiers", raw)
    return raw


def main():
    run()


if __name__ == "__main__":
    main()
