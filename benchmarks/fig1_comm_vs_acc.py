"""Paper Fig. 1: uploaded parameters vs accuracy per method (reads the
Table I + Table IV results; renders an ASCII scatter + CSV)."""
from __future__ import annotations

import json

from benchmarks.common import RESULTS, get_experiment, print_table, save_result


def run(preset: str = "paper", table1=None):
    if table1 is None:
        p = RESULTS / "table1_main.json"
        if p.exists():
            table1 = json.loads(p.read_text())
        else:
            from benchmarks import table1_main
            table1 = table1_main.run(preset)
    rows = []
    for m, res in table1.items():
        rows.append({"method": m, "uploaded_params": res["upload_params"],
                     "accuracy_pct": res["avg"] * 100})
    rows.sort(key=lambda r: r["uploaded_params"])
    print_table("Fig. 1 — upload size vs accuracy", rows,
                ["method", "uploaded_params", "accuracy_pct"])
    # ASCII scatter (log-x)
    import math
    print("\n  acc%  | log10(params uploaded)")
    for r in rows:
        x = 0 if r["uploaded_params"] == 0 else math.log10(r["uploaded_params"])
        bar = " " * int(x * 6) + "*"
        print(f"  {r['accuracy_pct']:5.1f} |{bar} {r['method']}")
    save_result("fig1_comm_vs_acc", rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
