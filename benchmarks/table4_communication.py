"""Paper Table IV + §VI-d: parameters uploaded per client.

Two scales are reported: (a) the paper's own constants (ResNet-18 11.69M,
20 rounds, C=60, 512-d CLIP) — validates the accounting model against the
published numbers; (b) our experiment's scale — validates the ≥99%
reduction claim end-to-end on the running system."""
from __future__ import annotations

import jax

from benchmarks.common import get_experiment, print_table, save_result
from repro.core import comm
from repro.models.classifiers import classifier_param_count, init_classifier


def run(preset: str = "paper", rounds: int = 10):
    exp = get_experiment(preset)
    C = exp.data.num_categories
    clf = classifier_param_count(
        init_classifier(jax.random.PRNGKey(0), exp.ocfg.classifier, C))

    ours = {m: comm.upload_params(m, num_categories=C, clf_params=clf,
                                  rounds=rounds)
            for m in ("local", "fedavg", "fedprox", "feddyn", "fedcado",
                      "feddisc", "oscar")}
    rows = [{"method": k, "uploaded_params": v,
             "vs_fedcado": f"{v / max(ours['fedcado'], 1):.4f}x"}
            for k, v in ours.items()]
    print_table("Table IV (our scale) — params uploaded per client", rows,
                ["method", "uploaded_params", "vs_fedcado"])
    red = comm.reduction_vs_sota(ours["oscar"],
                                 {"fedcado": ours["fedcado"],
                                  "feddisc": ours["feddisc"]})
    print(f"OSCAR upload reduction vs best DM-assisted SOTA: {red*100:.2f}% "
          f"(paper claims >=99%)")

    paper = comm.paper_scale_table4()
    rows_p = [{"method": k, "uploaded_params_M": round(v, 3)}
              for k, v in paper.items()]
    print_table("Table IV (paper constants, millions)", rows_p,
                ["method", "uploaded_params_M"])
    red_p = comm.reduction_vs_sota(paper["OSCAR"], paper)
    print(f"paper-scale reduction: {red_p*100:.2f}%")
    save_result("table4_communication",
                {"ours": ours, "paper": paper,
                 "reduction_ours": red, "reduction_paper": red_p})
    return {"ours": ours, "paper": paper}


def main():
    run()


if __name__ == "__main__":
    main()
