"""Kernel micro-benchmarks: wall time of the Pallas interpret path vs the
jnp oracle (CPU — correctness/parity harness; TPU timings are the perf
story in EXPERIMENTS.md §Perf, derived structurally from the dry-run).

``--only denoiser`` runs just the fused-vs-naive denoiser block — the CI
parity smoke: it ASSERTS ``dit_apply(use_pallas=True)`` matches the naive
reference within tolerance before timing anything.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, save_result

DENOISER_TOL = 2e-5


def _time(fn, *args, iters=5):
    out = fn(*args)                    # warm up / compile exactly once
    jax.block_until_ready(out)         # works on arrays and pytrees alike
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _perturb(params, key, scale=0.05):
    """adaLN-zero init zeroes the output head — perturb so the denoiser
    block's parity assert is not vacuously 0 == 0."""
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [
        a + scale * jax.random.normal(k, a.shape, a.dtype)
        for a, k in zip(leaves, keys)])


def run_micro():
    key = jax.random.PRNGKey(0)
    rows = []

    from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
    q = jax.random.normal(key, (2, 256, 4, 64))
    k = jax.random.normal(key, (2, 256, 2, 64))
    v = jax.random.normal(key, (2, 256, 2, 64))
    ref_fn = jax.jit(lambda q, k, v: fa_ref.attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)))
    rows.append({"name": "flash_attention_interp",
                 "us_per_call": _time(fa_ops.flash_attention, q, k, v),
                 "derived": "S=256 GQA4/2 hd=64"})
    rows.append({"name": "attention_ref_jit",
                 "us_per_call": _time(ref_fn, q, k, v),
                 "derived": "same shape"})

    from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref
    x = jax.random.normal(key, (4096, 1024))
    s = jax.random.normal(key, (1024,)) * 0.1
    rows.append({"name": "rmsnorm_interp",
                 "us_per_call": _time(rn_ops.rmsnorm, x, s),
                 "derived": "(4096,1024)"})
    rows.append({"name": "rmsnorm_ref_jit",
                 "us_per_call": _time(jax.jit(rn_ref.rmsnorm), x, s),
                 "derived": "same"})

    from repro.kernels.adaln_norm import ops as an_ops, ref as an_ref
    xa = jax.random.normal(key, (64, 257, 128))
    sa = jax.random.normal(key, (64, 128)) * 0.1
    ba = jax.random.normal(key, (64, 128)) * 0.1
    rows.append({"name": "adaln_norm_interp",
                 "us_per_call": _time(an_ops.adaln_norm, xa, sa, ba),
                 "derived": "(64,257,128)"})
    rows.append({"name": "adaln_norm_ref_jit",
                 "us_per_call": _time(jax.jit(an_ref.adaln_norm), xa, sa, ba),
                 "derived": "same"})

    from repro.kernels.cfg_fuse import ops as cfg_ops, ref as cfg_ref
    shape = (64, 16, 16, 3)
    ks = jax.random.split(key, 4)
    xs = [jax.random.normal(kk, shape) for kk in ks]
    rows.append({"name": "cfg_fuse_interp",
                 "us_per_call": _time(
                     lambda *a: cfg_ops.cfg_update(*a[:3], 7.5, 0.3, 0.5, a[3]),
                     *xs),
                 "derived": str(shape)})
    rows.append({"name": "cfg_fuse_ref_jit",
                 "us_per_call": _time(
                     jax.jit(lambda *a: cfg_ref.cfg_update(*a[:3], 7.5, 0.3, 0.5, a[3])),
                     *xs),
                 "derived": "same"})
    return rows


def run_denoiser():
    """Fused vs naive ``dit_apply`` block: parity gate, then wall-clock.

    CPU wall-clock compares the interpret-mode harness against the jitted
    naive denoiser — a correctness/overhead check, not the speed story
    (that is ``roofline.py``'s denoiser section).
    """
    from repro.configs.oscar import DiffusionConfig
    from repro.diffusion.dit import dit_apply, init_dit

    dc = DiffusionConfig()                           # paper-scale DiT
    key = jax.random.PRNGKey(0)
    B, img, C = 8, 16, 3
    params = _perturb(init_dit(key, dc, img, C), jax.random.fold_in(key, 1))
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, img, img, C))
    t = jax.random.randint(jax.random.fold_in(key, 3), (B,), 0,
                           dc.train_timesteps)
    y = jax.random.normal(jax.random.fold_in(key, 4), (B, dc.cond_dim))

    naive = jax.jit(lambda x, t, y: dit_apply(params, dc, x, t, y))
    fused = jax.jit(lambda x, t, y: dit_apply(params, dc, x, t, y,
                                              use_pallas=True))
    a, b = naive(x, t, y), fused(x, t, y)
    err = float(jnp.max(jnp.abs(a - b)))
    ref_scale = float(jnp.max(jnp.abs(a)))
    assert ref_scale > 1e-3, "parity check is vacuous (zero denoiser output)"
    assert err < DENOISER_TOL, (
        f"fused denoiser parity FAILED: max|Δ|={err:.2e} >= {DENOISER_TOL}")
    print(f"denoiser parity OK: max|Δ|={err:.2e} (tol {DENOISER_TOL}, "
          f"ref scale {ref_scale:.2f})")

    shape = f"B={B} {img}px d={dc.d_model} L={dc.num_layers}"
    return [
        {"name": "dit_naive_jit", "us_per_call": _time(naive, x, t, y),
         "derived": shape},
        {"name": "dit_fused_interp", "us_per_call": _time(fused, x, t, y),
         "derived": "same (parity asserted)"},
    ]


def run(only: str = "all"):
    rows = []
    if only in ("all", "micro"):
        rows += run_micro()
    if only in ("all", "denoiser"):
        rows += run_denoiser()
    print_table("Kernel microbench (CPU; Pallas interpret vs jnp oracle)",
                rows, ["name", "us_per_call", "derived"])
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if only == "all":
        save_result("kernels_bench", rows)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=["all", "micro", "denoiser"],
                    default="all")
    args = ap.parse_args(argv)
    run(args.only)


if __name__ == "__main__":
    main()
