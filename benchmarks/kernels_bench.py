"""Kernel micro-benchmarks: wall time of the Pallas interpret path vs the
jnp oracle (CPU — correctness/parity harness; TPU timings are the perf
story in EXPERIMENTS.md §Perf, derived structurally from the dry-run)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, save_result


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
    q = jax.random.normal(key, (2, 256, 4, 64))
    k = jax.random.normal(key, (2, 256, 2, 64))
    v = jax.random.normal(key, (2, 256, 2, 64))
    ref_fn = jax.jit(lambda q, k, v: fa_ref.attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)))
    rows.append({"name": "flash_attention_interp",
                 "us_per_call": _time(fa_ops.flash_attention, q, k, v),
                 "derived": "S=256 GQA4/2 hd=64"})
    rows.append({"name": "attention_ref_jit",
                 "us_per_call": _time(ref_fn, q, k, v),
                 "derived": "same shape"})

    from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref
    x = jax.random.normal(key, (4096, 1024))
    s = jax.random.normal(key, (1024,)) * 0.1
    rows.append({"name": "rmsnorm_interp",
                 "us_per_call": _time(rn_ops.rmsnorm, x, s),
                 "derived": "(4096,1024)"})
    rows.append({"name": "rmsnorm_ref_jit",
                 "us_per_call": _time(jax.jit(rn_ref.rmsnorm), x, s),
                 "derived": "same"})

    from repro.kernels.cfg_fuse import ops as cfg_ops, ref as cfg_ref
    shape = (64, 16, 16, 3)
    ks = jax.random.split(key, 4)
    xs = [jax.random.normal(kk, shape) for kk in ks]
    rows.append({"name": "cfg_fuse_interp",
                 "us_per_call": _time(
                     lambda *a: cfg_ops.cfg_update(*a[:3], 7.5, 0.3, 0.5, a[3]),
                     *xs),
                 "derived": str(shape)})
    rows.append({"name": "cfg_fuse_ref_jit",
                 "us_per_call": _time(
                     jax.jit(lambda *a: cfg_ref.cfg_update(*a[:3], 7.5, 0.3, 0.5, a[3])),
                     *xs),
                 "derived": "same"})

    print_table("Kernel microbench (CPU; Pallas interpret vs jnp oracle)",
                rows, ["name", "us_per_call", "derived"])
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    save_result("kernels_bench", rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
