"""Paper Table I: per-client + average test accuracy for all methods on
the feature-skew non-IID benchmark (ours: procedural multi-domain data;
see DESIGN.md §8 for the dataset substitution)."""
from __future__ import annotations

from benchmarks.common import acc_row, get_experiment, print_table, save_result

METHODS = ("local", "fedavg", "fedprox", "feddyn", "fedcado", "feddisc",
           "oscar")


def run(preset: str = "paper", methods=METHODS):
    exp = get_experiment(preset)
    rows, raw = [], {}
    for m in methods:
        # 20 FL rounds = the paper's FedAvg communication accounting
        res = exp.run(m, rounds=20)
        raw[m] = res
        rows.append(acc_row(m.capitalize() if m != "oscar" else "OSCAR", res,
                            exp.data.num_domains))
    cols = ["model"] + [f"client{i+1}" for i in range(exp.data.num_domains)] + ["avg"]
    print_table("Table I — client/avg test accuracy (%)", rows, cols)
    oscar_avg = raw["oscar"]["avg"]
    best_base = max(v["avg"] for k, v in raw.items() if k != "oscar")
    print(f"\nOSCAR avg {oscar_avg*100:.2f}% vs best baseline "
          f"{best_base*100:.2f}% -> {'BEATS' if oscar_avg >= best_base else 'below'}")
    save_result("table1_main", raw)
    return raw


def main():
    run()


if __name__ == "__main__":
    main()
