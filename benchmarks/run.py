"""Benchmark aggregator — one harness per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--preset quick|paper]
                                            [--only table1,table4,...]

Presets: ``paper`` (default) mirrors the paper's experiment scale within
the CPU budget (~30–45 min, DM pre-trained once and cached); ``quick``
is a minutes-scale smoke of every harness.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ALL = ("kernels", "synthesis", "table4", "roofline", "table1", "table2",
       "table3", "fig1", "guidance", "dropout")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default=os.environ.get("REPRO_BENCH_PRESET",
                                                       "paper"))
    ap.add_argument("--only", default=None,
                    help="comma list out of: " + ",".join(ALL))
    args = ap.parse_args()
    which = args.only.split(",") if args.only else list(ALL)

    t0 = time.perf_counter()
    print(f"== repro benchmarks (preset={args.preset}) ==", flush=True)

    table1_res = None
    if "kernels" in which:
        from benchmarks import kernels_bench
        kernels_bench.run()
    if "synthesis" in which:
        from benchmarks import synthesis_throughput
        synthesis_throughput.run(args.preset)
    if "table4" in which:
        from benchmarks import table4_communication
        table4_communication.run(args.preset)
    if "roofline" in which:
        from benchmarks import roofline
        roofline.main()
    if "table1" in which:
        from benchmarks import table1_main
        table1_res = table1_main.run(args.preset)
    if "table2" in which:
        from benchmarks import table2_classifiers
        table2_classifiers.run(args.preset)
    if "table3" in which:
        from benchmarks import table3_sample_count
        counts = (10, 20, 30) if args.preset == "quick" else (10, 20, 30, 40, 50)
        table3_sample_count.run(args.preset, counts=counts)
    if "fig1" in which:
        from benchmarks import fig1_comm_vs_acc
        fig1_comm_vs_acc.run(args.preset, table1=table1_res)
    if "guidance" in which:
        from benchmarks import guidance_sweep
        scales = (0.0, 2.0, 7.5) if args.preset == "quick" else guidance_sweep.SCALES
        guidance_sweep.run(args.preset, scales=scales)
    if "dropout" in which:
        from benchmarks import dropout_robustness
        rates = (1.0, 0.5) if args.preset == "quick" else dropout_robustness.RATES
        dropout_robustness.run(args.preset, rates=rates)

    print(f"\n== done in {time.perf_counter()-t0:.0f}s ==")


if __name__ == "__main__":
    main()
