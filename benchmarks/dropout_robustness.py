"""Beyond-paper experiment: client dropout / straggler robustness.

The paper motivates one-shot FL by dropout and stragglers (§I) but never
quantifies it — this bench does, at BOTH levels where the failure mode
bites:

* FL level — FedAvg accuracy degrades as per-round participation drops,
  while OSCAR's single communication round is immune (every client
  contributes its encodings exactly once, asynchronously);
* serving level — OSCAR concentrates all compute in the server's one
  D_syn burst, so the symmetric failure is a SERVING host dying
  mid-drain.  The elastic-membership layer (``serve/faults.py`` +
  ``serve/topology.py``) absorbs it: the drain marks the host failed,
  requeues its rows onto survivors, and finishes with BIT-IDENTICAL
  D_syn and zero lost requests — asserted here, so the two robustness
  claims ship (and regress) together.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import get_experiment, print_table, save_result
from repro.core.fl import run_fl

RATES = (1.0, 0.7, 0.5, 0.3)


def _serving_failover(hosts: int = 2):
    """One host of ``hosts`` killed mid-drain on a small synthesis
    workload: asserts bit-parity with the fault-free drain and zero
    lost requests (the deep version is ``synthesis_throughput.py
    --mode failover``)."""
    from repro.configs.oscar import DiffusionConfig
    from repro.diffusion.dit import init_dit
    from repro.diffusion.schedule import make_schedule
    from repro.serve import FaultInjector, SynthesisEngine

    dc = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                         sample_timesteps=4, train_timesteps=16)
    params = init_dit(jax.random.PRNGKey(0), dc, 16, 3)
    sched = make_schedule(dc.train_timesteps, dc.schedule)
    rng = np.random.default_rng(0)
    enc = rng.normal(size=(4, dc.cond_dim))
    enc = (enc / np.linalg.norm(enc, axis=-1, keepdims=True)).astype(
        np.float32)

    def drain(faults=None):
        eng = SynthesisEngine(params, dc, sched, image_size=16, cache=False,
                              granule=1, ragged=True, hosts=hosts,
                              faults=faults)
        rids = [eng.submit(e, c, 4) for c, e in enumerate(enc)]
        out = eng.run(jax.random.PRNGKey(9))
        assert sorted(out) == sorted(rids), "drain lost requests"
        return [out[r] for r in rids], eng

    clean, _ = drain()
    kill = hosts - 1
    failed, eng = drain(FaultInjector(schedule=[("window", kill, None)]))
    assert eng.topology.failed == {kill}, "host kill never landed"
    assert all(np.array_equal(a, b) for a, b in zip(clean, failed)), (
        "D_syn after host failover differs from fault-free — failover "
        "resampled instead of requeueing")
    return {"hosts": hosts, "killed_host": kill,
            "requeued_rows": eng.metrics.get("failover.requeued_rows"),
            "lost_requests": 0, "bit_identical": True}


def run(preset: str = "paper", rates=RATES, rounds: int = 10):
    exp = get_experiment(preset)
    oscar = exp.run("oscar")
    rows = [{"method": "OSCAR (1 round)", "participation": "-",
             "avg_acc_pct": oscar["avg"] * 100,
             "upload_per_client": oscar["upload_params"]}]
    raw = {"oscar": oscar["avg"]}
    for p in rates:
        key = jax.random.fold_in(jax.random.PRNGKey(11), int(p * 100))
        _, m, up = run_fl(key, exp.data, rounds=rounds, participation=p)
        rows.append({"method": "FedAvg", "participation": p,
                     "avg_acc_pct": m["avg"] * 100, "upload_per_client": up})
        raw[f"fedavg@{p}"] = m["avg"]
        print(f"  fedavg p={p}: {m['avg']*100:.2f}%", flush=True)
    print_table("Client-dropout robustness (beyond-paper)", rows,
                ["method", "participation", "avg_acc_pct",
                 "upload_per_client"])
    fo = _serving_failover()
    raw["serving_failover"] = fo
    print(f"  serving failover: host {fo['killed_host']}/{fo['hosts']} "
          f"killed mid-drain -> {fo['requeued_rows']} rows requeued, "
          f"{fo['lost_requests']} lost, D_syn bit-identical", flush=True)
    save_result("dropout_robustness", raw)
    return raw


def main():
    run()


if __name__ == "__main__":
    main()
