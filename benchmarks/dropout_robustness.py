"""Beyond-paper experiment: client dropout / straggler robustness.

The paper motivates one-shot FL by dropout and stragglers (§I) but never
quantifies it — this bench does: FedAvg accuracy degrades as per-round
participation drops, while OSCAR's single communication round is immune
(every client contributes its encodings exactly once, asynchronously)."""
from __future__ import annotations

import jax

from benchmarks.common import get_experiment, print_table, save_result
from repro.core.fl import run_fl

RATES = (1.0, 0.7, 0.5, 0.3)


def run(preset: str = "paper", rates=RATES, rounds: int = 10):
    exp = get_experiment(preset)
    oscar = exp.run("oscar")
    rows = [{"method": "OSCAR (1 round)", "participation": "-",
             "avg_acc_pct": oscar["avg"] * 100,
             "upload_per_client": oscar["upload_params"]}]
    raw = {"oscar": oscar["avg"]}
    for p in rates:
        key = jax.random.fold_in(jax.random.PRNGKey(11), int(p * 100))
        _, m, up = run_fl(key, exp.data, rounds=rounds, participation=p)
        rows.append({"method": "FedAvg", "participation": p,
                     "avg_acc_pct": m["avg"] * 100, "upload_per_client": up})
        raw[f"fedavg@{p}"] = m["avg"]
        print(f"  fedavg p={p}: {m['avg']*100:.2f}%", flush=True)
    print_table("Client-dropout robustness (beyond-paper)", rows,
                ["method", "participation", "avg_acc_pct",
                 "upload_per_client"])
    save_result("dropout_robustness", raw)
    return raw


def main():
    run()


if __name__ == "__main__":
    main()
