"""Paper Table III: impact of synthesised samples per category (10..50) —
accuracy rises then saturates/regresses past a threshold."""
from __future__ import annotations

import jax

from benchmarks.common import acc_row, get_experiment, print_table, save_result
from repro.core.classifier_train import evaluate_per_domain, fit_global
from repro.core.oscar import client_encodings, synthesize

COUNTS = (10, 20, 30, 40, 50)


def run(preset: str = "paper", counts=COUNTS):
    exp = get_experiment(preset)
    enc, present = client_encodings(exp.fm, exp.data)
    key = jax.random.PRNGKey(7)
    rows, raw = [], {}
    # synthesise once at max count, subsample per setting (paired samples)
    kmax = max(counts)
    syn_x, syn_y = synthesize(key, exp.dm_params, exp.ocfg.diffusion,
                              exp.sched, enc, present, kmax,
                              image_size=exp.ocfg.data.image_size,
                              service=exp.service)
    per_slot = kmax  # images are grouped per (client,category) slot
    import numpy as np
    n_slots = len(syn_x) // per_slot
    for k in counts:
        sel = np.concatenate([np.arange(s * per_slot, s * per_slot + k)
                              for s in range(n_slots)])
        gp = fit_global(jax.random.fold_in(key, k), exp.ocfg.classifier,
                        exp.data.num_categories, syn_x[sel], syn_y[sel],
                        steps=exp.ocfg.classifier_steps)
        metrics = evaluate_per_domain(gp, exp.ocfg.classifier, exp.data)
        raw[k] = metrics
        rows.append(acc_row(str(k), metrics, exp.data.num_domains))
        print(f"  samples/cat={k}: avg {metrics['avg']*100:.2f}%", flush=True)
    cols = ["model"] + [f"client{i+1}" for i in range(exp.data.num_domains)] + ["avg"]
    print_table("Table III — samples per category vs accuracy (%)", rows, cols)
    save_result("table3_sample_count", raw)
    return raw


def main():
    run()


if __name__ == "__main__":
    main()
