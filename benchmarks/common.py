"""Shared benchmark plumbing: one cached Experiment per config, CSV/table
printing, result persistence."""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

RESULTS = Path(__file__).resolve().parent / "results"
RESULTS.mkdir(exist_ok=True)

_EXPERIMENT = {}


def _timed(fn, *args, **kw):
    """Run ``fn(*args, **kw)`` and return ``(seconds, result)`` measured
    on the monotonic ``time.perf_counter`` clock — wall timings must
    never ride ``time.time()``, which steps under NTP adjustments."""
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out


def get_experiment(preset: str = "paper"):
    """Cached Experiment (data + pre-trained frozen DM)."""
    from repro.configs.oscar import (DataConfig, DiffusionConfig, OscarConfig)
    if preset in _EXPERIMENT:
        return _EXPERIMENT[preset]
    if preset == "quick":
        ocfg = OscarConfig(
            data=DataConfig(num_categories=5, train_per_cat_dom=8,
                            test_per_cat_dom=4),
            diffusion=DiffusionConfig(pretrain_steps=600, batch_size=64),
            classifier_steps=150)
    else:  # "paper" scale (CPU-budgeted analogue of the paper's setting)
        ocfg = OscarConfig(
            # Data-starved clients: the paper's clients hold 30 images/cat
            # of 224×224 NATURAL images — deeply data-poor relative to the
            # task.  Our 16×16 procedural task is far simpler, so matching
            # the paper's relative data poverty (Local weakest, DM-assisted
            # methods strongest) needs proportionally fewer client images.
            # The DM's knowledge is client-independent (the disjoint
            # pretrain pool = Stable Diffusion's web-scale analogue).
            data=DataConfig(num_categories=10, train_per_cat_dom=10,
                            test_per_cat_dom=8,
                            pretrain_pool_per_cat_dom=120),
            diffusion=DiffusionConfig(d_model=144, pretrain_steps=6000,
                                      batch_size=128),
            classifier_steps=400,
            # paper Table I uses the Table-III-optimal 30 samples/category
            samples_per_category=30)
    from repro.core.experiment import Experiment
    _EXPERIMENT[preset] = Experiment(ocfg)
    return _EXPERIMENT[preset]


def print_table(title: str, rows: list[dict], cols: list[str]):
    print(f"\n### {title}")
    widths = {c: max(len(c), *(len(_fmt(r.get(c, ""))) for r in rows))
              for c in cols}
    print(" | ".join(c.ljust(widths[c]) for c in cols))
    print("-|-".join("-" * widths[c] for c in cols))
    for r in rows:
        print(" | ".join(_fmt(r.get(c, "")).ljust(widths[c]) for c in cols))
    sys.stdout.flush()


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


def save_result(name: str, obj):
    (RESULTS / f"{name}.json").write_text(json.dumps(obj, indent=1,
                                                     default=str))


def acc_row(method: str, metrics: dict, num_clients: int = 6) -> dict:
    row = {"model": method}
    for r in range(num_clients):
        k = f"client{r + 1}"
        if k in metrics:
            row[k] = metrics[k] * 100
    row["avg"] = metrics["avg"] * 100
    return row
