"""SynthesisEngine throughput: batched wave path vs the seed-era
per-method chunk loops, on the same D_syn workload.

Workload shape mirrors the OSCAR server (paper §IV): R clients × C
categories, k samples per (client, category) encoding.  Three runs:

* ``seed_loop``   — the pre-refactor path: concatenate all conditioning
  rows, then fixed-stride chunks (512) with a ragged tail, each shape
  compiling its own reverse trajectory;
* ``engine_cold`` — SynthesisEngine wave packing: near-uniform waves →
  ONE compiled trajectory for the whole workload;
* ``engine_warm`` — the same requests resubmitted (how the benchmark
  tables re-synthesise per sweep point): served from the engine cache.

Writes ``results/BENCH_synthesis.json`` via the shared harness.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_result
from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.sampler import sample_cfg
from repro.diffusion.schedule import make_schedule
from repro.serve.synthesis import SynthesisEngine

SEED_CHUNK = 512          # the pre-refactor chunk stride (core/oscar.py)


def _workload(preset: str):
    if preset == "quick":
        return dict(R=3, C=4, k=10, steps=8,
                    dc=DiffusionConfig(d_model=64, num_layers=2, num_heads=2))
    return dict(R=6, C=10, k=10, steps=20,
                dc=DiffusionConfig(d_model=128, num_layers=4, num_heads=4))


def _seed_loop(params, dc, sched, conds, key, *, steps):
    """Verbatim shape of the pre-refactor core/oscar.py::synthesize loop."""
    outs = []
    for i in range(0, len(conds), SEED_CHUNK):
        key, kc = jax.random.split(key)
        x = sample_cfg(params, dc, sched, jnp.asarray(conds[i:i + SEED_CHUNK]),
                       kc, image_size=16, num_steps=steps)
        outs.append(np.asarray(x))
    return np.concatenate(outs)


def run(preset: str = "paper"):
    w = _workload(preset)
    dc, steps = w["dc"], w["steps"]
    R, C, k = w["R"], w["C"], w["k"]
    key = jax.random.PRNGKey(0)
    # throughput only — a random-init DM denoises just as expensively
    params = init_dit(key, dc, 16, 3)
    sched = make_schedule(dc.train_timesteps, dc.schedule)
    enc = np.random.default_rng(0).normal(size=(R, C, dc.cond_dim))
    enc = (enc / np.linalg.norm(enc, axis=-1, keepdims=True)).astype(np.float32)
    conds = np.concatenate([np.repeat(enc[r, c][None], k, axis=0)
                            for r in range(R) for c in range(C)])
    n = len(conds)
    print(f"  workload: {R} clients x {C} categories x {k} samples "
          f"= {n} images, {steps} steps")

    t0 = time.time()
    seed_out = _seed_loop(params, dc, sched, conds, key, steps=steps)
    t_seed = time.time() - t0

    eng = SynthesisEngine(params, dc, sched, image_size=16)

    def submit_all():
        return [eng.submit(enc[r, c], c, k, num_steps=steps)
                for r in range(R) for c in range(C)]

    t0 = time.time()
    rids = submit_all()
    out = eng.run(key)
    t_cold = time.time() - t0
    assert sum(out[rid].shape[0] for rid in rids) == n == len(seed_out)

    rids2 = submit_all()
    t0 = time.time()
    out2 = eng.run(jax.random.PRNGKey(1))
    t_warm = time.time() - t0
    assert all(np.array_equal(out2[b], out[a])
               for a, b in zip(rids, rids2))

    rows = [
        {"path": "seed_loop", "wall_s": t_seed, "img_per_s": n / t_seed},
        {"path": "engine_cold", "wall_s": t_cold, "img_per_s": n / t_cold},
        {"path": "engine_warm", "wall_s": t_warm,
         "img_per_s": n / max(t_warm, 1e-9)},
    ]
    print_table("Synthesis throughput — engine waves vs seed chunk loops",
                rows, ["path", "wall_s", "img_per_s"])
    print(f"  engine stats: {eng.stats}")
    res = {"preset": preset, "images": n, "steps": steps,
           "seed_loop_s": t_seed, "engine_cold_s": t_cold,
           "engine_warm_s": t_warm,
           "speedup_cold": t_seed / t_cold,
           "speedup_warm": t_seed / max(t_warm, 1e-9),
           "engine_stats": dict(eng.stats)}
    save_result("BENCH_synthesis", res)
    return res


def main():
    run()


if __name__ == "__main__":
    main()
