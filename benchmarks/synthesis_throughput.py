"""Synthesis serving throughput: SynthesisEngine waves + the
SynthesisService streaming/persistence layers vs the seed-era per-method
chunk loops, on the same D_syn workload.

Workload shape mirrors the OSCAR server (paper §IV): R clients × C
categories, k samples per (client, category) encoding.  Five runs:

* ``seed_loop``    — the pre-refactor path: concatenate all conditioning
  rows, then fixed-stride chunks (512) with a ragged tail, each shape
  compiling its own reverse trajectory;
* ``engine_cold``  — SynthesisEngine wave packing: near-uniform waves →
  ONE compiled trajectory for the whole workload;
* ``engine_warm``  — the same requests resubmitted (how the benchmark
  tables re-synthesise per sweep point): served from the engine cache;
* ``streaming``    — half the requests arrive mid-drain through a
  SynthesisService poll; open waves absorb them (compare padded rows
  against ``two_snapshots``, the same trace drained snapshot-style);
* ``store_warm``   — a COLD process (fresh engine, fresh store handle)
  against the warm on-disk D_syn store: zero sampler calls;
* ``ragged``       — a MIXED (guidance, steps) workload (the guidance
  sweep's groups next to a second step count) served grouped vs ragged:
  grouped compiles one trajectory per (guidance, steps) group and pads
  each group's waves separately; ragged waves carry per-row guidance and
  step counts, so every classifier-free row shares one compiled geometry.
  Reported: padded rows, distinct compiled shapes, wall-clock, and
  ``row_iters_scheduled`` vs ``row_iters_active`` — the honest device-
  work split (one-shot ragged schedules its frozen right-aligned rows
  through the denoiser; only the active count is useful work).  The
  comparison ASSERTS ragged pads strictly fewer rows and compiles
  strictly fewer shapes, so a regression fails CI's smoke run;
* ``compacted``    — the same mixed workload through the iteration-
  compacted scheduler (``compaction="full"``): one scan segment per
  activation epoch, so scheduled row-iterations must equal the TRUE sum
  of per-row steps with 0 padded rows, and D_syn must be bit-identical
  to the one-shot ragged run — both ASSERTED, gating CI's smoke run.

* ``mixed``        — a mixed-GUIDANCE-MODE workload: the cfg sweep next
  to per-category uploaded classifiers (Eq. 4 rows) and unconditional
  draws, grouped vs the MERGED scheduler (all three modes in the same
  ragged waves; uncond as s=0 null-cond rows).  ASSERTS — gating CI's
  smoke run — zero legacy clf/uncond wave groups, strictly fewer padded
  rows and compiled shapes than grouped, 0 padded rows under full
  compaction, and D_syn BIT-IDENTICAL across compaction, host counts
  (1/2/4), and a mid-drain host kill.

* ``multihost``    — the same mixed workload drained over ``--hosts``
  SIMULATED HOSTS through the topology/placement layer
  (``serve/topology.py``): per-host ingress queues, contiguous per-host
  wave windows against one wave-resident scalar table (the segment-
  offset ``cfg_fuse`` path).  ASSERTS D_syn is bit-identical to the
  single-host drain (placement invariance) and that full compaction
  schedules exactly its active row-iterations PER HOST — both gating
  CI's smoke run.

* ``failover``     — the mixed workload over ``--hosts`` hosts with one
  host KILLED mid-drain through the fault-injection layer
  (``serve/faults.py``): the drain marks it failed, requeues its rows
  onto the survivors, and finishes.  ASSERTS — gating CI's smoke run —
  that D_syn is BIT-IDENTICAL to the fault-free drain (failover is a
  placement change, never a resample), that zero requests are lost, and
  that the survivor per-host sums still equal the global counters.

* ``fused``        — the mixed workload with the FUSED DENOISER
  (``use_pallas=True``: Pallas flash-attention + adaln_norm inside
  ``dit_apply``) vs naive, in ragged and compacted modes.  ASSERTS the
  fp32 parity gates: fused ragged == fused compacted bit-identically,
  and fused vs naive within float tolerance — gating CI's smoke run.

* ``trace``        — the mixed workload drained once UNTRACED and once
  under a live span ``Tracer`` in every scheduling mode (grouped /
  ragged / compacted / multihost), reporting per-request e2e p50/p99
  next to wall-clock.  ASSERTS — gating CI's smoke run — that D_syn is
  BIT-IDENTICAL with tracing on vs off in every mode (observability must
  never touch computation) and that the exported Chrome trace passes the
  schema gate with one timeline track per simulated host.  ``--trace
  out.json`` writes the Perfetto-loadable timeline (+ metrics dump).

Writes ``results/BENCH_synthesis.json`` via the shared harness
(``--mode ragged`` / ``--mode compacted`` / ``--mode mixed`` /
``--mode multihost`` / ``--mode failover`` / ``--mode fused`` /
``--mode trace`` re-run only their comparison and merge it into an
existing results file).
"""
from __future__ import annotations

import argparse
import json
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS, _timed, print_table, save_result
from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.sampler import sample_cfg
from repro.diffusion.schedule import make_schedule
from repro.obs import Tracer, chrome_trace, validate_chrome_trace, write_trace
from repro.serve import (FaultInjector, SynthesisEngine, SynthesisService,
                         SynthesisStore)

SEED_CHUNK = 512          # the pre-refactor chunk stride (core/oscar.py)


def _workload(preset: str):
    if preset == "smoke":          # CI regression canary: seconds-scale
        return dict(R=2, C=2, k=4, steps=4,
                    dc=DiffusionConfig(d_model=32, num_layers=1, num_heads=2))
    if preset == "quick":
        return dict(R=3, C=4, k=10, steps=8,
                    dc=DiffusionConfig(d_model=64, num_layers=2, num_heads=2))
    return dict(R=6, C=10, k=10, steps=20,
                dc=DiffusionConfig(d_model=128, num_layers=4, num_heads=4))


def _seed_loop(params, dc, sched, conds, key, *, steps):
    """Verbatim shape of the pre-refactor core/oscar.py::synthesize loop."""
    outs = []
    for i in range(0, len(conds), SEED_CHUNK):
        key, kc = jax.random.split(key)
        x = sample_cfg(params, dc, sched, jnp.asarray(conds[i:i + SEED_CHUNK]),
                       kc, image_size=16, num_steps=steps)
        outs.append(np.asarray(x))
    return np.concatenate(outs)


def _bench_streaming(params, dc, sched, enc, *, steps, k):
    """Half the clients' uploads queued up front, the rest arriving
    mid-drain — one client (C requests) per poll, the serving-time
    analogue of a straggler upload landing while waves are in flight."""
    R, C = enc.shape[:2]
    upfront = [(r, c) for r in range(R // 2) for c in range(C)]
    late_clients = [[(r, c) for c in range(C)] for r in range(R // 2, R)]

    def fresh_service():
        eng = SynthesisEngine(params, dc, sched, image_size=16, cache=False)
        return SynthesisService(eng, key=0)

    # snapshot baseline: the late arrivals become a second drain
    snap = fresh_service()
    for r, c in upfront:
        snap.submit(enc[r, c], c, k, num_steps=steps)

    def snap_drains():
        snap.drain()
        for client in late_clients:
            for r, c in client:
                snap.submit(enc[r, c], c, k, num_steps=steps)
        snap.drain()

    t_snap, _ = _timed(snap_drains)

    strm = fresh_service()
    for r, c in upfront:
        strm.submit(enc[r, c], c, k, num_steps=steps)
    trace = list(late_clients)

    def poll():
        if not trace:
            return False
        for r, c in trace.pop(0):
            strm.submit(enc[r, c], c, k, num_steps=steps)
        return True

    t_strm, _ = _timed(strm.drain, poll=poll)
    return {"two_snapshots_s": t_snap, "streaming_s": t_strm,
            "two_snapshots_padded": snap.stats["padded"],
            "streaming_padded": strm.stats["padded"],
            "streamed_requests": strm.stats["streamed"]}


def _bench_mixed(params, dc, sched, enc, *, steps, k, compacted: bool):
    """Grouped vs ragged (vs compacted) on an identical MIXED workload:
    the R×C requests round-robin over (guidance, steps) combos — the
    serving-time shape of a guidance sweep running next to requests at
    another step budget.  With ``compacted`` the same workload also runs
    through the iteration-compacted scheduler (``compaction="full"``) and
    its outputs are asserted BIT-IDENTICAL to the one-shot ragged run."""
    reqs = _mixed_reqs(enc, steps)
    true_row_iters = sum(k * s for _, _, _, s in reqs)

    def run_mode(ragged, compaction=None):
        eng = SynthesisEngine(params, dc, sched, image_size=16, cache=False,
                              ragged=ragged, compaction=compaction)
        rids = [eng.submit(enc[r, c], c, k, guidance=g, num_steps=s)
                for r, c, g, s in reqs]
        wall, out = _timed(eng.run, jax.random.PRNGKey(2))
        assert all(out[rid].shape[0] == k for rid in rids)
        return wall, dict(eng.stats), [out[rid] for rid in rids]

    t_grp, st_grp, _ = run_mode(False)
    t_rag, st_rag, out_rag = run_mode(True)
    res = {"combos": len({(g, s) for _, _, g, s in reqs}),
           "grouped_s": t_grp, "ragged_s": t_rag,
           "grouped_padded": st_grp["padded"],
           "ragged_padded": st_rag["padded"],
           "grouped_compiled": st_grp["compiled_shapes"],
           "ragged_compiled": st_rag["compiled_shapes"],
           "grouped_waves": st_grp["waves"], "ragged_waves": st_rag["waves"],
           "grouped_row_iters_scheduled": st_grp["row_iters_scheduled"],
           "grouped_row_iters_active": st_grp["row_iters_active"],
           "ragged_row_iters_scheduled": st_rag["row_iters_scheduled"],
           "ragged_row_iters_active": st_rag["row_iters_active"]}
    # honest accounting: active iters count only REAL rows' own steps, so
    # every mode agrees on the workload's useful work no matter how much
    # padding or frozen riding its schedule added on top
    assert (res["grouped_row_iters_active"]
            == res["ragged_row_iters_active"] == true_row_iters), (
        f"active row_iters grouped {res['grouped_row_iters_active']} / "
        f"ragged {res['ragged_row_iters_active']} != true sum "
        f"{true_row_iters} — padding leaked into the useful-work stat")
    # the CI regression gate: cross-group wave fusion must strictly beat
    # per-group packing on both padding and compile count
    assert res["ragged_padded"] < res["grouped_padded"], (
        f"ragged padded {res['ragged_padded']} rows >= grouped "
        f"{res['grouped_padded']} — ragged wave fusion regressed")
    assert res["ragged_compiled"] < res["grouped_compiled"], (
        f"ragged compiled {res['ragged_compiled']} shapes >= grouped "
        f"{res['grouped_compiled']} — ragged wave fusion regressed")
    if not compacted:
        return res, None

    t_cmp, st_cmp, out_cmp = run_mode(True, compaction="full")
    comp = {"compacted_s": t_cmp,
            "compacted_padded": st_cmp["padded"],
            "compacted_compiled": st_cmp["compiled_shapes"],
            "compacted_waves": st_cmp["waves"],
            "compacted_segments": st_cmp["segments"],
            "compacted_row_iters_scheduled": st_cmp["row_iters_scheduled"],
            "compacted_row_iters_active": st_cmp["row_iters_active"],
            "true_row_iters": true_row_iters}
    # the compute-skipping regression gate: full compaction must schedule
    # EXACTLY the true sum of per-row steps (no frozen rows riding the
    # denoiser, no alignment padding) and change no output bit
    assert comp["compacted_padded"] == 0, (
        f"compacted padded {comp['compacted_padded']} rows != 0 — wave "
        f"packing regressed")
    assert (comp["compacted_row_iters_scheduled"]
            == comp["compacted_row_iters_active"] == true_row_iters), (
        f"compacted scheduled/active row_iters "
        f"{comp['compacted_row_iters_scheduled']}/"
        f"{comp['compacted_row_iters_active']} != true sum "
        f"{true_row_iters} — compaction is leaving frozen rows scheduled")
    assert (comp["compacted_row_iters_scheduled"]
            < res["ragged_row_iters_scheduled"]), (
        "compaction scheduled no fewer row_iters than the one-shot "
        "ragged scan")
    assert all(np.array_equal(a, b) for a, b in zip(out_rag, out_cmp)), (
        "compacted D_syn differs from ragged — the schedule leaked into "
        "row values")
    return res, comp


def _mixed_reqs(enc, steps):
    """The mixed (guidance, steps) request set every comparison serves:
    R×C requests round-robin over four (guidance, steps) combos."""
    R, C = enc.shape[:2]
    half = max(steps // 2, 2)
    combos = [(1.5, steps), (4.0, steps), (7.5, half), (1.5, half)]
    return [(r, c, *combos[i % len(combos)])
            for i, (r, c) in enumerate((r, c) for r in range(R)
                                       for c in range(C))]


# module-level classifier closures: stable identity keeps the merged
# engines' classifier-ensemble jit caches shared across comparison runs
def _clf_center(x, labels):
    return -jnp.sum(x ** 2, axis=(1, 2, 3))


def _clf_pull(x, labels):
    pull = labels.astype(x.dtype)[:, None, None, None]
    return -jnp.sum((x - 0.1 * pull) ** 2, axis=(1, 2, 3))


_CLFS = (_clf_center, _clf_pull)


def _bench_mixed_guidance(params, dc, sched, enc, *, steps, k, hosts,
                          preset):
    """Grouped vs MERGED on a mixed-GUIDANCE-MODE workload: the cfg
    (guidance, steps) sweep next to per-category uploaded classifiers
    (Eq. 4 ε̂-correction rows) and unconditional draws.  Grouped packs
    one wave group per mode×combo; the merged scheduler routes all three
    modes into the SAME ragged waves (uncond as s=0 null-cond rows, clf
    rows batching their classifier over the wave).  ASSERTS — gating
    CI's smoke run — that the merged drain dispatches ZERO legacy
    grouped clf/uncond waves, pads and compiles strictly less than
    grouped, that full compaction pads exactly 0, and that D_syn is
    BIT-IDENTICAL across compaction, host counts, and a mid-drain host
    kill.  Wall-clock is gated merged < grouped at the paper preset
    (smoke/quick runs are compile-dominated)."""
    R, C = enc.shape[:2]
    half = max(steps // 2, 2)
    cfg_reqs = _mixed_reqs(enc, steps)
    clf_reqs = [(c, _CLFS[c % len(_CLFS)], steps if c % 2 else half)
                for c in range(C)]
    unc_reqs = [(c, half if c % 2 else steps) for c in range(min(C, 4))]
    true_row_iters = (sum(k * s for _, _, _, s in cfg_reqs)
                      + sum(k * s for _, _, s in clf_reqs)
                      + sum(k * s for _, s in unc_reqs))

    def submit_all(eng):
        rids = [eng.submit(enc[r, c], c, k, guidance=g, num_steps=s)
                for r, c, g, s in cfg_reqs]
        rids += [eng.submit_classifier_guided(fn, c, k, guidance=1.0,
                                              num_steps=s, group=("clf", c))
                 for c, fn, s in clf_reqs]
        rids += [eng.submit_unconditional(k, category=c, num_steps=s)
                 for c, s in unc_reqs]
        return rids

    def run_mode(**kw):
        eng = SynthesisEngine(params, dc, sched, image_size=16,
                              cache=False, **kw)
        rids = submit_all(eng)
        wall, out = _timed(eng.run, jax.random.PRNGKey(3))
        assert all(out[rid].shape[0] == k for rid in rids)
        return wall, eng, [out[rid] for rid in rids]

    t_grp, eng_grp, out_grp = run_mode(ragged=False)
    t_mrg, eng_mrg, out_mrg = run_mode(ragged=True)
    st_grp, st_mrg = dict(eng_grp.stats), dict(eng_mrg.stats)
    legacy = sum(1 for sh in eng_mrg.traj_shapes
                 if sh[0] in ("clf", "uncond"))
    res = {"cfg_requests": len(cfg_reqs), "clf_requests": len(clf_reqs),
           "uncond_requests": len(unc_reqs),
           "grouped_s": t_grp, "merged_s": t_mrg,
           "grouped_padded": st_grp["padded"],
           "merged_padded": st_mrg["padded"],
           "grouped_compiled": st_grp["compiled_shapes"],
           "merged_compiled": st_mrg["compiled_shapes"],
           "grouped_waves": st_grp["waves"],
           "merged_waves": st_mrg["merged_waves"],
           "legacy_mode_waves": legacy,
           "merged_row_iters_active": st_mrg["row_iters_active"]}
    # the scheduler-merge gate: clf/uncond must never fall back to their
    # legacy single-mode wave groups once the merged queue serves them
    assert legacy == 0, (
        f"{legacy} legacy clf/uncond wave shapes dispatched by the merged "
        f"scheduler: {sorted(eng_mrg.traj_shapes)}")
    assert res["merged_row_iters_active"] == true_row_iters, (
        f"merged active row_iters {res['merged_row_iters_active']} != true "
        f"sum {true_row_iters} — padding leaked into the useful-work stat")
    assert res["merged_padded"] < res["grouped_padded"], (
        f"merged padded {res['merged_padded']} rows >= grouped "
        f"{res['grouped_padded']} — cross-mode wave fusion regressed")
    assert res["merged_compiled"] < res["grouped_compiled"], (
        f"merged compiled {res['merged_compiled']} shapes >= grouped "
        f"{res['grouped_compiled']} — cross-mode wave fusion regressed")
    if preset == "paper":
        assert t_mrg < t_grp, (
            f"merged wall {t_mrg:.2f}s >= grouped {t_grp:.2f}s at paper "
            f"scale — the merged scheduler lost its throughput edge")

    # full compaction on the merged queue: padding stays under the
    # near-uniform planner's bound (< one granule per wave — exactly 0
    # whenever the workload divides), and no schedule change moves a bit
    t_cmp, eng_cmp, out_cmp = run_mode(ragged=True, compaction="full")
    res["compacted_s"] = t_cmp
    res["compacted_padded"] = eng_cmp.stats["padded"]
    assert (res["compacted_padded"]
            < eng_cmp.granule * max(eng_cmp.stats["waves"], 1)), (
        f"compacted merged drain padded {res['compacted_padded']} rows "
        f">= granule x waves — wave planning regressed")
    assert all(np.array_equal(a, b) for a, b in zip(out_mrg, out_cmp)), (
        "compacted merged D_syn differs from one-shot merged")

    # placement invariance: the SAME mixed workload over 1/2/4 simulated
    # hosts, plus one host killed mid-drain — every row bit-identical
    for h in sorted({2, hosts, 4}):
        _, eng_h, out_h = run_mode(ragged=True, hosts=h)
        assert all(np.array_equal(a, b) for a, b in zip(out_mrg, out_h)), (
            f"merged D_syn differs at hosts={h} — placement leaked into "
            f"row values")
        ph = eng_h.stats["per_host"]
        assert sum(p["rows"] for p in ph) == eng_h.stats["generated"]
    res["parity_hosts"] = sorted({1, 2, hosts, 4})
    _, eng_f, out_f = run_mode(
        ragged=True, hosts=2,
        faults=FaultInjector(schedule=[("window", 0, 0)]))
    assert eng_f.topology.failed == {0}, "injected host kill never landed"
    assert all(np.array_equal(a, b) for a, b in zip(out_mrg, out_f)), (
        "merged D_syn differs after a mid-drain host kill — failover "
        "resampled instead of replacing")
    res["failover_parity"] = True
    return res


def _print_mixed_guidance(mg: dict):
    print_table(
        "Merged guidance modes — cfg + classifier-guided + uncond, one "
        "scheduler",
        [{"mode": "grouped", "wall_s": mg["grouped_s"],
          "padded": mg["grouped_padded"], "compiled": mg["grouped_compiled"],
          "waves": mg["grouped_waves"]},
         {"mode": "merged", "wall_s": mg["merged_s"],
          "padded": mg["merged_padded"], "compiled": mg["merged_compiled"],
          "waves": mg["merged_waves"]},
         {"mode": "merged+compacted", "wall_s": mg["compacted_s"],
          "padded": mg["compacted_padded"], "compiled": "-",
          "waves": "-"}],
        ["mode", "wall_s", "padded", "compiled", "waves"])
    print(f"  {mg['cfg_requests']} cfg + {mg['clf_requests']} clf + "
          f"{mg['uncond_requests']} uncond requests, "
          f"{mg['legacy_mode_waves']} legacy mode waves, bit-identical "
          f"across hosts {mg['parity_hosts']} + mid-drain host kill")


def _bench_fused(params, dc, sched, enc, *, steps, k):
    """Fused denoiser (``use_pallas=True`` → Pallas flash-attention +
    adaln_norm inside ``dit_apply``) vs naive on the mixed workload, in
    ragged AND compacted modes.  Params are PERTURBED away from the
    adaLN-zero init (whose zero denoiser output would make every parity
    assert vacuous).  ASSERTS — gating CI's smoke run — that in fp32 the
    fused ragged and fused compacted drains stay BIT-identical (one flag
    setting ⇒ one D_syn, regardless of packing) and that fused vs naive
    stays within float tolerance (online softmax reorders accumulation,
    so bit equality across the FLAG is not expected).  CPU wall-clock
    times the interpret-mode harness — a correctness/overhead number; the
    TPU speed story is ``roofline.py``'s denoiser section."""
    reqs = _mixed_reqs(enc, steps)
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(7), len(leaves))
    params = jax.tree.unflatten(treedef, [
        a + 0.05 * jax.random.normal(kk, a.shape, a.dtype)
        for a, kk in zip(leaves, keys)])

    def run_mode(use_pallas, compaction=None):
        eng = SynthesisEngine(params, dc, sched, image_size=16, cache=False,
                              ragged=True, compaction=compaction,
                              use_pallas=use_pallas)
        rids = [eng.submit(enc[r, c], c, k, guidance=g, num_steps=s)
                for r, c, g, s in reqs]
        wall, out = _timed(eng.run, jax.random.PRNGKey(4))
        return wall, [out[rid] for rid in rids]

    t_nr, out_nr = run_mode(False)
    t_fr, out_fr = run_mode(True)
    t_nc, out_nc = run_mode(False, compaction="full")
    t_fc, out_fc = run_mode(True, compaction="full")
    assert all(np.array_equal(a, b) for a, b in zip(out_fr, out_fc)), (
        "fused ragged vs fused compacted D_syn differ — the fused flag "
        "broke packing invariance")
    assert all(np.array_equal(a, b) for a, b in zip(out_nr, out_nc)), (
        "naive ragged vs naive compacted D_syn differ")
    # Per-CALL fp32 parity is ~1e-6 (kernels_bench gates it at 2e-5), but
    # the reverse trajectory COMPOUNDS it: every step feeds the slightly
    # perturbed x_t back through the denoiser under guidance scales up to
    # 7.5, so the fused-vs-naive gap grows roughly exponentially in step
    # count (measured: 1.6e-6 at smoke's 4 steps, 3.2e-3 at paper's 20).
    # Gate tight where compounding is short, bounded at paper depth; the
    # regardless-of-depth guarantee is the BIT-identity across modes above.
    tol = 5e-4 if steps <= 8 else 2e-2
    err = max(float(np.max(np.abs(a - b)))
              for a, b in zip(out_nr, out_fr))
    assert err < tol, (
        f"fused vs naive D_syn fp32 max|Δ|={err:.2e} >= {tol} — the fused "
        f"denoiser drifted past float tolerance")
    return {"ragged_naive_s": t_nr, "ragged_fused_s": t_fr,
            "compacted_naive_s": t_nc, "compacted_fused_s": t_fc,
            "fp32_max_abs_diff": err, "fp32_tol": tol,
            "bit_identical_across_modes": True,
            "note": "CPU interpret wall-clock (parity harness); TPU "
                    "position in results/roofline_denoiser.json"}


def _print_fused(f: dict):
    print_table(
        "Fused denoiser — mixed workload, CPU interpret parity harness",
        [{"mode": "ragged_naive", "wall_s": f["ragged_naive_s"]},
         {"mode": "ragged_fused", "wall_s": f["ragged_fused_s"]},
         {"mode": "compacted_naive", "wall_s": f["compacted_naive_s"]},
         {"mode": "compacted_fused", "wall_s": f["compacted_fused_s"]}],
        ["mode", "wall_s"])
    print(f"  fused==naive fp32 max|Δ| {f['fp32_max_abs_diff']:.2e} "
          f"(tol {f['fp32_tol']}); fused ragged==compacted bit-identical")


def _bench_multihost(params, dc, sched, enc, *, steps, k, hosts: int,
                     preset: str = "paper"):
    """Topology-placed serving on the mixed workload: the same requests
    drained single-host (ragged oracle) and over ``hosts`` simulated
    hosts (ragged and compacted, per-host workers on).  ASSERTS —
    gating CI's smoke run — that D_syn is BIT-IDENTICAL across
    topologies (row noise is keyed by request identity, so placement
    must be invisible), that the compacted run schedules exactly its
    active row-iterations PER HOST, that the per-host breakdown sums to
    the global counters — and the CONCURRENCY gate: at paper/quick
    scale the H-host drain's wall-clock must not exceed single-host on
    the same workload (the PR 5 sequential windows were ~1.5-3x
    SLOWER); the smoke preset gates overlap structurally instead (CI
    CPUs may not speed up): with every host's fence held at a barrier
    until all arrive, the hosts' ``device.scan`` spans must overlap in
    wall-clock time — impossible under the old in-order fence loop.

    Every mode times its SECOND drain of the workload: the first drain
    compiles the mode's wave/window executables, so the gate compares
    steady-state serving walls and is independent of which earlier
    benchmark modes happened to warm this process's jit cache (compile
    sharing across hosts is asserted separately via the engines'
    ``compiled_shapes`` being equal)."""
    reqs = _mixed_reqs(enc, steps)

    def run_mode(tracer=None, sync_hook=None, **kw):
        eng = SynthesisEngine(params, dc, sched, image_size=16, cache=False,
                              granule=1,
                              **({"tracer": tracer} if tracer else {}),
                              **kw)
        if sync_hook is not None:
            eng._sync_hook = sync_hook
        for r, c, g, s in reqs:        # warmup drain: compile everything
            eng.submit(enc[r, c], c, k, guidance=g, num_steps=s)
        eng.run(jax.random.PRNGKey(3))
        rids = [eng.submit(enc[r, c], c, k, guidance=g, num_steps=s)
                for r, c, g, s in reqs]
        wall, out = _timed(eng.run, jax.random.PRNGKey(3))
        return wall, dict(eng.stats), [out[rid] for rid in rids]

    t_one, st_one, out_one = run_mode(ragged=True)
    t_rag, st_rag, out_rag = run_mode(ragged=True, hosts=hosts)
    t_cmp, st_cmp, out_cmp = run_mode(compaction="full", hosts=hosts)
    # compile sharing: row_offset is a traced operand and placed waves
    # plan near-uniform, so H equal-quota windows ride as many compiled
    # executables as the single-host drain — hosts don't multiply the
    # compile bill
    assert st_rag["compiled_shapes"] == st_one["compiled_shapes"], (
        f"{hosts}-host ragged drain compiled "
        f"{st_rag['compiled_shapes']} shapes vs single-host "
        f"{st_one['compiled_shapes']} — window executables are "
        f"specializing per host again")
    res = {"hosts": hosts, "single_host_s": t_one,
           "multihost_ragged_s": t_rag, "multihost_compacted_s": t_cmp,
           "per_host_rows": [p["rows"] for p in st_cmp["per_host"]],
           "multihost_padded": st_cmp["padded"],
           "row_iters_scheduled": st_cmp["row_iters_scheduled"],
           "row_iters_active": st_cmp["row_iters_active"]}
    # the placement-invariance gate: host count must change no output bit
    for name, outs in (("ragged", out_rag), ("compacted", out_cmp)):
        assert all(np.array_equal(a, b) for a, b in zip(out_one, outs)), (
            f"{hosts}-host {name} D_syn differs from single-host — "
            f"placement leaked into row values")
    # per-host accounting: sums must equal the global counters, and full
    # compaction must schedule exactly each host's active row-iterations
    for st in (st_rag, st_cmp):
        per = st["per_host"]
        assert sum(p["rows"] + p["padded"] for p in per) \
            == st["scheduled_rows"]
        assert sum(p["rows"] for p in per) == st["generated"]
        assert sum(p["row_iters_scheduled"] for p in per) \
            == st["row_iters_scheduled"]
        assert sum(p["row_iters_active"] for p in per) \
            == st["row_iters_active"]
    for p in st_cmp["per_host"]:
        assert p["row_iters_scheduled"] == p["row_iters_active"], (
            f"host {p}: compacted scheduled != active — frozen rows are "
            f"riding the denoiser under the topology")
    # the concurrency gate
    if preset == "smoke":
        import threading
        tracer = Tracer()
        barrier = threading.Barrier(hosts, timeout=30.0)

        def hook(site, host, wave):
            if site == "fence":
                try:
                    barrier.wait()
                except threading.BrokenBarrierError:
                    pass          # a wave with fewer windows than hosts
        _, _, out_ov = run_mode(ragged=True, hosts=hosts, tracer=tracer,
                                sync_hook=hook)
        assert all(np.array_equal(a, b) for a, b in zip(out_one, out_ov))
        scans = [sp for sp in tracer.spans if sp.name == "device.scan"]
        by_host = {}
        for sp in scans:
            by_host.setdefault(sp.attrs.get("host"), []).append(sp)
        hs = sorted(by_host)
        assert len(hs) >= 2 and any(
            a.start < b.end and b.start < a.end
            for i, h in enumerate(hs) for j in hs[i + 1:]
            for a in by_host[h] for b in by_host[j]), (
            "host windows fenced serially — the per-host workers are "
            "not overlapping device scans")
        res["scan_overlap"] = True
    else:
        # paper/quick: the topology must actually be ≤ single-host now
        # (2% jitter headroom for wall-clock noise)
        assert t_rag <= t_one * 1.02, (
            f"{hosts}-host ragged drain ({t_rag:.2f}s) slower than "
            f"single-host ({t_one:.2f}s) — the concurrent placed drain "
            f"regressed to a correctness harness")
        res["wall_gate"] = f"multihost {t_rag:.2f}s <= single {t_one:.2f}s"
    return res


def _bench_failover(params, dc, sched, enc, *, steps, k, hosts: int):
    """Elastic-membership failover on the mixed workload: the same
    requests drained single-host (oracle), over ``hosts`` fault-free
    hosts, and over ``hosts`` hosts with one host KILLED mid-drain
    (``FaultInjector`` ``window`` schedule), in ragged AND compacted
    modes.  ASSERTS — gating CI's smoke run — that D_syn is
    BIT-IDENTICAL across all three (failover is a placement change,
    never a resample: row noise is keyed by request identity), that
    every submitted request is served (zero loss), that the dead host is
    marked failed with its queued rows requeued onto survivors, and that
    the survivor per-host sums still equal the global counters."""
    reqs = _mixed_reqs(enc, steps)
    kill = hosts - 1
    # kill mid-drain when the workload spans several waves (quick/paper);
    # smoke's single wave dies at its first dispatch — still a full
    # requeue onto the survivors
    kill_wave = 1 if len(reqs) * k > 2 * 128 else 0

    def run_mode(**kw):
        eng = SynthesisEngine(params, dc, sched, image_size=16, cache=False,
                              granule=1, **kw)
        rids = [eng.submit(enc[r, c], c, k, guidance=g, num_steps=s)
                for r, c, g, s in reqs]
        wall, out = _timed(eng.run, jax.random.PRNGKey(6))
        assert sorted(out) == sorted(rids), (
            "drain lost or invented requests")
        return wall, eng, [out[rid] for rid in rids]

    t_one, _, out_one = run_mode(ragged=True)
    t_ff, _, out_ff = run_mode(ragged=True, hosts=hosts)
    assert all(np.array_equal(a, b) for a, b in zip(out_one, out_ff))
    res = {"hosts": hosts, "killed_host": kill, "kill_wave": kill_wave,
           "single_host_s": t_one, "fault_free_s": t_ff}
    for name, kw in (("ragged", {"ragged": True}),
                     ("compacted", {"compaction": "full"})):
        t_f, eng, out_f = run_mode(
            hosts=hosts,
            faults=FaultInjector(schedule=[("window", kill, kill_wave)]),
            **kw)
        assert eng.faults.pending == 0, (
            f"{name}: the scheduled host kill never fired — host {kill} "
            f"dispatched no window at wave {kill_wave}")
        assert eng.topology.failed == {kill}, (
            f"{name}: host {kill} not marked failed after its kill")
        assert eng.metrics.get("fault.host_lost") == 1
        requeued = eng.metrics.get("failover.requeued_rows")
        assert requeued > 0, (
            f"{name}: failover requeued nothing — the dead host's queue "
            f"was not migrated to survivors")
        # the failover-determinism gate: killing a host changes no bit
        assert all(np.array_equal(a, b)
                   for a, b in zip(out_one, out_f)), (
            f"{name}: D_syn after host {kill} failover differs from the "
            f"fault-free drain — failover resampled instead of replacing")
        st = eng.stats
        per = st["per_host"]
        assert sum(p["rows"] + p["padded"] for p in per) \
            == st["scheduled_rows"]
        assert sum(p["rows"] for p in per) == st["generated"]
        assert sum(p["row_iters_active"] for p in per) \
            == st["row_iters_active"]
        res[f"failover_{name}_s"] = t_f
        res[f"{name}_requeued_rows"] = requeued
        res[f"{name}_survivor_rows"] = [p["rows"] for p in per]
    return res


def _print_failover(fo: dict):
    print_table(
        f"Failover — {fo['hosts']} hosts, host {fo['killed_host']} killed "
        f"at wave {fo['kill_wave']}",
        [{"mode": "single_host", "wall_s": fo["single_host_s"]},
         {"mode": "fault_free", "wall_s": fo["fault_free_s"]},
         {"mode": "failover_ragged", "wall_s": fo["failover_ragged_s"]},
         {"mode": "failover_compacted",
          "wall_s": fo["failover_compacted_s"]}],
        ["mode", "wall_s"])
    print(f"  requeued {fo['ragged_requeued_rows']} rows (ragged) / "
          f"{fo['compacted_requeued_rows']} (compacted) onto survivors "
          f"{fo['ragged_survivor_rows']}, zero lost requests, "
          f"bit-identical to fault-free")


def _print_multihost(mh: dict):
    print_table(
        f"Multi-host placed serving — {mh['hosts']} simulated hosts",
        [{"mode": "single_host", "wall_s": mh["single_host_s"]},
         {"mode": "multihost_ragged", "wall_s": mh["multihost_ragged_s"]},
         {"mode": "multihost_compacted", "wall_s": mh["multihost_compacted_s"]}],
        ["mode", "wall_s"])
    print(f"  per-host rows {mh['per_host_rows']}, padded "
          f"{mh['multihost_padded']}, scheduled==active "
          f"{mh['row_iters_scheduled']}=={mh['row_iters_active']}, "
          f"bit-identical across topologies")


def _print_ragged(ragged: dict, compacted: dict | None = None):
    rows = [
        {"mode": "grouped", "wall_s": ragged["grouped_s"],
         "padded": ragged["grouped_padded"],
         "compiled": ragged["grouped_compiled"],
         "waves": ragged["grouped_waves"],
         "iters_sched": ragged["grouped_row_iters_scheduled"],
         "iters_active": ragged["grouped_row_iters_active"]},
        {"mode": "ragged", "wall_s": ragged["ragged_s"],
         "padded": ragged["ragged_padded"],
         "compiled": ragged["ragged_compiled"],
         "waves": ragged["ragged_waves"],
         "iters_sched": ragged["ragged_row_iters_scheduled"],
         "iters_active": ragged["ragged_row_iters_active"]},
    ]
    if compacted is not None:
        rows.append(
            {"mode": "compacted", "wall_s": compacted["compacted_s"],
             "padded": compacted["compacted_padded"],
             "compiled": compacted["compacted_compiled"],
             "waves": compacted["compacted_waves"],
             "iters_sched": compacted["compacted_row_iters_scheduled"],
             "iters_active": compacted["compacted_row_iters_active"]})
    print_table("Ragged waves — mixed (guidance, steps) workload", rows,
                ["mode", "wall_s", "padded", "compiled", "waves",
                 "iters_sched", "iters_active"])


def _bench_store(params, dc, sched, enc, *, steps, k, store_dir):
    """Warm an on-disk store, then serve the workload from a cold process
    (fresh engine + fresh store handle): zero sampler calls.  Both runs
    are traced, so per-request e2e latency histograms fall out — and the
    warm path's p99 must sit STRICTLY below the cold path's p50 (gated:
    if serving from disk is not categorically faster than synthesising,
    the store regressed)."""
    R, C = enc.shape[:2]

    def run_cold():
        eng = SynthesisEngine(params, dc, sched, image_size=16)
        svc = SynthesisService(eng, key=1, store=SynthesisStore(store_dir),
                               tracer=Tracer())
        futs = [svc.submit(enc[r, c], c, k, num_steps=steps)
                for r in range(R) for c in range(C)]
        wall, outs = _timed(svc.gather, futs)
        e2e = svc.engine.metrics.get("request.e2e_latency", default=None)
        return wall, outs, svc.stats, e2e

    t_cold, outs1, _, e2e_cold = run_cold()       # generates + spills
    t_warm, outs2, stats, e2e_warm = run_cold()   # fresh process, warm disk
    assert stats["generated"] == 0, "warm store must skip the sampler"
    assert all(np.array_equal(a, b) for a, b in zip(outs1, outs2))
    assert e2e_cold["count"] == e2e_warm["count"] == R * C
    # the latency gate: every warm request (p99) beats the cold median
    assert e2e_warm["p99"] < e2e_cold["p50"], (
        f"warm-store p99 e2e {e2e_warm['p99']:.4f}s >= cold p50 "
        f"{e2e_cold['p50']:.4f}s — store serving lost its latency edge")
    return {"store_cold_s": t_cold, "store_warm_s": t_warm,
            "store_warm_generated": stats["generated"],
            "store_hits": stats["store_hits"],
            "cold_e2e_p50_s": e2e_cold["p50"],
            "cold_e2e_p99_s": e2e_cold["p99"],
            "warm_e2e_p50_s": e2e_warm["p50"],
            "warm_e2e_p99_s": e2e_warm["p99"]}


def _bench_trace(params, dc, sched, enc, *, steps, k, hosts: int,
                 trace_path=None):
    """The observability gate: the mixed workload drained untraced and
    under a live ``Tracer`` in every scheduling mode.  ASSERTS D_syn is
    BIT-IDENTICAL with tracing on vs off (spans and lifecycle stamps
    observe the drain; they must never key noise or order work) and that
    the multihost run's exported Chrome trace passes the schema gate
    with one timeline track per simulated host.  Reports per-request e2e
    p50/p99 next to wall-clock for every mode; ``trace_path`` writes the
    Perfetto-loadable timeline + metrics dump."""
    reqs = _mixed_reqs(enc, steps)
    modes = {"grouped": {},
             "ragged": {"ragged": True},
             "compacted": {"compaction": "full"},
             "multihost": {"compaction": "full", "hosts": hosts,
                           "granule": 1}}
    res = {}
    mh_tracer = mh_svc = None
    for name, kw in modes.items():

        def run_mode(tracer):
            eng = SynthesisEngine(params, dc, sched, image_size=16,
                                  cache=False, **kw)
            svc = SynthesisService(eng, key=5, tracer=tracer)
            futs = [svc.submit(enc[r, c], c, k, guidance=g, num_steps=s)
                    for r, c, g, s in reqs]
            wall, outs = _timed(svc.gather, futs)
            return wall, outs, svc

        t_off, out_off, _ = run_mode(None)
        tracer = Tracer()
        t_on, out_on, svc = run_mode(tracer)
        # the determinism gate: tracing must be value-invisible
        assert all(np.array_equal(a, b)
                   for a, b in zip(out_off, out_on)), (
            f"{name}: D_syn with tracing enabled differs from disabled — "
            f"observability leaked into computation")
        e2e = svc.engine.metrics.get("request.e2e_latency", default=None)
        qw = svc.engine.metrics.get("request.queue_wait", default=None)
        res[name] = {"wall_untraced_s": t_off, "wall_traced_s": t_on,
                     "spans": len(tracer.spans),
                     "requests": e2e["count"],
                     "e2e_p50_s": e2e["p50"], "e2e_p99_s": e2e["p99"]}
        if qw:
            res[name]["queue_wait_p50_s"] = qw["p50"]
            res[name]["queue_wait_p99_s"] = qw["p99"]
        if name == "multihost":
            mh_tracer, mh_svc = tracer, svc
    # the export gate: the multihost timeline must validate with one
    # track per simulated host (written to --trace when requested)
    if trace_path is not None:
        obj = write_trace(trace_path, mh_tracer,
                          registry=mh_svc.engine.metrics, hosts=hosts)
        res["trace_file"] = str(trace_path)
    else:
        obj = chrome_trace(mh_tracer, hosts=hosts)
    res["trace_events"] = validate_chrome_trace(obj, require_hosts=hosts)
    return res


def _print_trace(tr: dict):
    rows = [{"mode": name, "wall_s": b["wall_traced_s"],
             "spans": b["spans"], "e2e_p50_ms": b["e2e_p50_s"] * 1e3,
             "e2e_p99_ms": b["e2e_p99_s"] * 1e3}
            for name, b in tr.items() if isinstance(b, dict)]
    print_table("Traced drains — tracing on, bit-identical to off", rows,
                ["mode", "wall_s", "spans", "e2e_p50_ms", "e2e_p99_ms"])
    print(f"  exported trace: {tr.get('trace_file', '(not written)')} "
          f"({tr['trace_events']} events, schema-validated)")


def _merge_result(preset: str, updates: dict, drop: tuple = ()):
    """Merge one mode's block into an existing BENCH_synthesis.json —
    the single-mode CI steps must not clobber the full run's numbers —
    never mixing presets in one file."""
    path = RESULTS / "BENCH_synthesis.json"
    res = json.loads(path.read_text()) if path.exists() else {}
    if res.get("preset") != preset:
        res = {"preset": preset}
    res.update(updates)
    for key in drop:
        res.pop(key, None)
    save_result("BENCH_synthesis", res)
    return res


def run(preset: str = "paper", mode: str = "all", hosts: int = 2,
        trace_path=None):
    w = _workload(preset)
    dc, steps = w["dc"], w["steps"]
    R, C, k = w["R"], w["C"], w["k"]
    key = jax.random.PRNGKey(0)
    # throughput only — a random-init DM denoises just as expensively
    params = init_dit(key, dc, 16, 3)
    sched = make_schedule(dc.train_timesteps, dc.schedule)
    enc = np.random.default_rng(0).normal(size=(R, C, dc.cond_dim))
    enc = (enc / np.linalg.norm(enc, axis=-1, keepdims=True)).astype(np.float32)
    conds = np.concatenate([np.repeat(enc[r, c][None], k, axis=0)
                            for r in range(R) for c in range(C)])
    n = len(conds)
    print(f"  workload: {R} clients x {C} categories x {k} samples "
          f"= {n} images, {steps} steps")

    if mode == "fused":
        # fused-denoiser parity + wall-clock only (the CI fused gate):
        # merge into an existing results file rather than clobbering it
        fused = _bench_fused(params, dc, sched, enc, steps=steps, k=k)
        _print_fused(fused)
        return _merge_result(preset, {"fused": fused})

    if mode == "multihost":
        # topology regression only (the CI multi-host gate): merge into an
        # existing results file rather than clobbering the full run
        mh = _bench_multihost(params, dc, sched, enc, steps=steps, k=k,
                              hosts=hosts, preset=preset)
        _print_multihost(mh)
        return _merge_result(preset, {"multihost": mh})

    if mode == "failover":
        # elastic-membership regression only (the CI failover gate):
        # host-kill bit-parity + zero-loss + survivor accounting, merged
        # into an existing results file rather than clobbering the full run
        fo = _bench_failover(params, dc, sched, enc, steps=steps, k=k,
                             hosts=hosts)
        _print_failover(fo)
        return _merge_result(preset, {"failover": fo})

    if mode == "trace":
        # observability regression only (the CI trace gate): tracing
        # on/off bit-parity + schema-validated export, merged into an
        # existing results file rather than clobbering the full run
        tr = _bench_trace(params, dc, sched, enc, steps=steps, k=k,
                          hosts=hosts, trace_path=trace_path)
        _print_trace(tr)
        return _merge_result(preset, {"trace": tr})

    if mode == "mixed":
        # merged guidance-mode regression only (the CI mixed gate):
        # zero legacy mode waves + padding/compile wins + bit-parity
        # across hosts and a mid-drain kill, merged into an existing
        # results file rather than clobbering the full run
        mg = _bench_mixed_guidance(params, dc, sched, enc, steps=steps,
                                   k=k, hosts=hosts, preset=preset)
        _print_mixed_guidance(mg)
        return _merge_result(preset, {"mixed_guidance": mg})

    if mode in ("ragged", "compacted"):
        # mixed-workload comparison only (the CI regression step): merge
        # into an existing results file rather than clobbering the full
        # run.  ``compacted`` additionally runs the iteration-compacted
        # scheduler and its row_iters/bit-parity asserts.
        ragged, compacted = _bench_mixed(params, dc, sched, enc, steps=steps,
                                         k=k, compacted=mode == "compacted")
        _print_ragged(ragged, compacted)
        if compacted is not None:
            return _merge_result(preset, {"ragged": ragged,
                                          "compacted": compacted})
        # a ragged-only refresh must not leave an older run's compacted
        # block paired with the fresh numbers
        return _merge_result(preset, {"ragged": ragged},
                             drop=("compacted",))

    t_seed, seed_out = _timed(_seed_loop, params, dc, sched, conds, key,
                              steps=steps)

    eng = SynthesisEngine(params, dc, sched, image_size=16)

    def submit_all():
        return [eng.submit(enc[r, c], c, k, num_steps=steps)
                for r in range(R) for c in range(C)]

    def cold_drain():
        return submit_all(), eng.run(key)

    t_cold, (rids, out) = _timed(cold_drain)
    assert sum(out[rid].shape[0] for rid in rids) == n == len(seed_out)

    rids2 = submit_all()
    t_warm, out2 = _timed(eng.run, jax.random.PRNGKey(1))
    assert all(np.array_equal(out2[b], out[a])
               for a, b in zip(rids, rids2))

    streaming = _bench_streaming(params, dc, sched, enc, steps=steps, k=k)
    with tempfile.TemporaryDirectory(prefix="dsyn_store_") as store_dir:
        store = _bench_store(params, dc, sched, enc, steps=steps, k=k,
                             store_dir=store_dir)
    ragged, compacted = _bench_mixed(params, dc, sched, enc, steps=steps,
                                     k=k, compacted=True)
    mixed_guidance = _bench_mixed_guidance(params, dc, sched, enc,
                                           steps=steps, k=k, hosts=hosts,
                                           preset=preset)
    multihost = _bench_multihost(params, dc, sched, enc, steps=steps, k=k,
                                 hosts=hosts, preset=preset)
    failover = _bench_failover(params, dc, sched, enc, steps=steps, k=k,
                               hosts=hosts)
    fused = _bench_fused(params, dc, sched, enc, steps=steps, k=k)
    trace = _bench_trace(params, dc, sched, enc, steps=steps, k=k,
                         hosts=hosts, trace_path=trace_path)

    rows = [
        {"path": "seed_loop", "wall_s": t_seed, "img_per_s": n / t_seed},
        {"path": "engine_cold", "wall_s": t_cold, "img_per_s": n / t_cold},
        {"path": "engine_warm", "wall_s": t_warm,
         "img_per_s": n / max(t_warm, 1e-9)},
        {"path": "streaming", "wall_s": streaming["streaming_s"],
         "img_per_s": n / max(streaming["streaming_s"], 1e-9)},
        {"path": "store_warm", "wall_s": store["store_warm_s"],
         "img_per_s": n / max(store["store_warm_s"], 1e-9)},
    ]
    print_table("Synthesis throughput — engine waves vs seed chunk loops",
                rows, ["path", "wall_s", "img_per_s"])
    _print_ragged(ragged, compacted)
    _print_mixed_guidance(mixed_guidance)
    _print_multihost(multihost)
    _print_failover(failover)
    _print_fused(fused)
    _print_trace(trace)
    print(f"  streaming: padded {streaming['streaming_padded']} rows vs "
          f"{streaming['two_snapshots_padded']} snapshot-drained, "
          f"{streaming['streamed_requests']} requests admitted mid-drain")
    print(f"  store: warm rerun generated {store['store_warm_generated']} "
          f"rows ({store['store_hits']} served from disk)")
    print(f"  engine stats: {eng.stats}")
    res = {"preset": preset, "images": n, "steps": steps,
           "seed_loop_s": t_seed, "engine_cold_s": t_cold,
           "engine_warm_s": t_warm,
           "speedup_cold": t_seed / t_cold,
           "speedup_warm": t_seed / max(t_warm, 1e-9),
           "engine_stats": dict(eng.stats),
           "ragged": ragged, "compacted": compacted,
           "mixed_guidance": mixed_guidance,
           "multihost": multihost, "failover": failover,
           "fused": fused, "trace": trace,
           **streaming, **store}
    save_result("BENCH_synthesis", res)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="paper",
                    choices=("smoke", "quick", "paper"))
    ap.add_argument("--mode", default="all",
                    choices=("all", "ragged", "compacted", "mixed",
                             "multihost", "failover", "fused", "trace"),
                    help="'ragged' runs only the grouped-vs-ragged mixed-"
                         "workload comparison and merges it into an "
                         "existing BENCH_synthesis.json; 'compacted' adds "
                         "the iteration-compacted scheduler with its "
                         "row_iters == true-sum and bit-parity asserts; "
                         "'mixed' serves cfg + classifier-guided + uncond "
                         "through the merged scheduler, gating zero "
                         "legacy mode waves, padding/compile wins over "
                         "grouped, and bit-parity across host counts and "
                         "a mid-drain host kill; "
                         "'multihost' runs the topology-placed comparison "
                         "(--hosts simulated hosts) gating single-host "
                         "bit-parity and the per-host scheduled==active "
                         "invariant; 'failover' kills one of --hosts "
                         "hosts mid-drain and gates bit-parity vs the "
                         "fault-free drain, zero lost requests, and "
                         "survivor accounting; 'fused' runs the fused-vs-"
                         "naive "
                         "denoiser comparison (ragged+compacted) with its "
                         "fp32 parity gates; 'trace' runs every mode "
                         "traced vs untraced, gating tracing bit-parity "
                         "and the exported Chrome trace schema")
    ap.add_argument("--hosts", type=int, default=2,
                    help="simulated host count for --mode multihost/trace")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="write the Perfetto-loadable Chrome trace (+ "
                         "metrics dump) of the traced multihost drain here")
    args = ap.parse_args()
    run(args.preset, args.mode, args.hosts, trace_path=args.trace)


if __name__ == "__main__":
    main()
