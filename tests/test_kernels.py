"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp ref.py oracles."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.cfg_fuse import ops as cfg_ops
from repro.kernels.cfg_fuse import ref as cfg_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rmsnorm import ops as rn_ops
from repro.kernels.rmsnorm import ref as rn_ref


@pytest.mark.parametrize(
    "S,Hq,Hkv,hd,causal,window,cap,dt",
    [
        (64, 4, 4, 64, True, 0, 0.0, jnp.float32),
        (128, 4, 2, 64, True, 0, 0.0, jnp.float32),     # GQA
        (100, 8, 1, 128, True, 0, 0.0, jnp.bfloat16),   # MQA + ragged + bf16
        (128, 4, 2, 128, True, 32, 50.0, jnp.float32),  # sliding + softcap
        (96, 2, 2, 64, False, 0, 0.0, jnp.float32),     # encoder (hubert)
        (256, 4, 4, 80, True, 0, 0.0, jnp.float32),     # hd=80 (hubert)
        (32, 4, 4, 256, True, 0, 0.0, jnp.float32),     # hd=256 (gemma2)
    ])
def test_flash_attention_matches_oracle(rng_key, S, Hq, Hkv, hd, causal,
                                        window, cap, dt):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, S, Hq, hd), dt)
    k = jax.random.normal(ks[1], (2, S, Hkv, hd), dt)
    v = jax.random.normal(ks[2], (2, S, Hkv, hd), dt)
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 softcap=cap)
    ref = fa_ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=causal,
                           window=window, softcap=cap).transpose(0, 2, 1, 3)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    assert jnp.max(jnp.abs(out.astype(jnp.float32) -
                           ref.astype(jnp.float32))) < tol


def test_flash_attention_cross_length(rng_key):
    """Sq != Sk (prefill continuation shape)."""
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 4, 64))
    v = jax.random.normal(ks[2], (1, 128, 4, 64))
    out = fa_ops.flash_attention(q, k, v, causal=False)
    ref = fa_ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3),
                           causal=False).transpose(0, 2, 1, 3)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("shape,dt", [
    ((4, 32, 256), jnp.float32),
    ((3, 7, 512), jnp.bfloat16),
    ((128, 1024), jnp.float32),
    ((5, 96), jnp.float32),
])
def test_rmsnorm_matches_oracle(rng_key, shape, dt):
    x = jax.random.normal(rng_key, shape, dt)
    s = jax.random.normal(jax.random.fold_in(rng_key, 1), (shape[-1],)) * 0.1
    out = rn_ops.rmsnorm(x, s)
    ref = rn_ref.rmsnorm(x, s)
    tol = 5e-2 if dt == jnp.bfloat16 else 1e-5
    assert jnp.max(jnp.abs(out.astype(jnp.float32) -
                           ref.astype(jnp.float32))) <= tol


@pytest.mark.parametrize("shape", [(4, 16, 16, 3), (7, 8, 8, 1), (1, 33)])
@pytest.mark.parametrize("s,ab_t,ab_prev", [
    (7.5, 0.31, 0.52), (0.0, 0.9, 0.95), (3.0, 0.05, 0.11)])
def test_cfg_fuse_matches_oracle(rng_key, shape, s, ab_t, ab_prev):
    ks = jax.random.split(rng_key, 4)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    out = cfg_ops.cfg_update(x, ec, eu, s, ab_t, ab_prev, z)
    ref = cfg_ref.cfg_update(x, ec, eu, s, ab_t, ab_prev, z)
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


def test_cfg_guidance_zero_is_conditional(rng_key):
    """s=0 ⇒ ε̂ = ε_c exactly (Eq. 8 degenerate case)."""
    ks = jax.random.split(rng_key, 4)
    x, ec, eu, z = (jax.random.normal(k, (2, 8, 8, 3)) for k in ks)
    a = cfg_ref.cfg_update(x, ec, eu, 0.0, 0.5, 0.7, z)
    b = cfg_ref.ancestral_step(x, ec, 0.5, 0.7, z)
    assert jnp.allclose(a, b)


@pytest.mark.parametrize("rows", [8, 248, 304, 520])
def test_cfg_fuse_partial_blocks(rng_key, rows):
    """Row counts around/above BLOCK_ROWS=256, incl. non-divisible grids —
    the (rows, 128) layout exercises partial trailing blocks directly."""
    ks = jax.random.split(rng_key, 4)
    shape = (rows, 128)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    out = cfg_ops.cfg_update(x, ec, eu, 3.0, 0.3, 0.6, z)
    ref = cfg_ref.cfg_update(x, ec, eu, 3.0, 0.3, 0.6, z)
    assert out.shape == shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


def test_cfg_fuse_ragged_flatten(rng_key):
    """A shape whose flat size divides neither 128 lanes nor the 8-row
    sublane alignment — ops.py must pad and exactly un-pad."""
    ks = jax.random.split(rng_key, 4)
    shape = (5, 97, 13)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    out = cfg_ops.cfg_update(x, ec, eu, 1.5, 0.2, 0.4, z)
    ref = cfg_ref.cfg_update(x, ec, eu, 1.5, 0.2, 0.4, z)
    assert out.shape == shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


@pytest.mark.parametrize("shape", [(6, 16, 16, 3), (3, 8, 8, 1), (2, 33),
                                   (5, 97, 13)])
def test_cfg_fuse_rowwise_matches_oracle(rng_key, shape):
    """Ragged-wave kernel: per-row (s, ᾱ_t, ᾱ_prev, active) scalars vs the
    rowwise jnp oracle, incl. non-lane-aligned per-image flatten."""
    B = shape[0]
    ks = jax.random.split(rng_key, 4)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    s = jnp.linspace(0.0, 7.5, B)
    ab_t = jnp.linspace(0.05, 0.9, B)
    ab_prev = jnp.linspace(0.11, 0.95, B)
    act = (jnp.arange(B) % 3 != 1).astype(jnp.float32)
    out = cfg_ops.cfg_update_rowwise(x, ec, eu, s, ab_t, ab_prev, z, act)
    ref = cfg_ref.cfg_update_rowwise(x, ec, eu, s, ab_t, ab_prev, z, act)
    assert out.shape == shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


def test_cfg_fuse_rowwise_uniform_matches_scalar_kernel(rng_key):
    """All rows agreeing on (s, ᾱ_t, ᾱ_prev) must reproduce the scalar
    cfg_fuse kernel BIT-exactly — the contract that lets ragged waves
    replace per-group waves without changing a single pixel."""
    ks = jax.random.split(rng_key, 4)
    shape = (4, 16, 16, 3)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    for s, ab_t, ab_prev in [(7.5, 0.31, 0.52), (0.0, 0.9, 0.95),
                             (1.5, 0.05, 0.11)]:
        row = cfg_ops.cfg_update_rowwise(
            x, ec, eu, jnp.full((4,), s), jnp.full((4,), ab_t),
            jnp.full((4,), ab_prev), z, jnp.ones((4,)))
        scal = cfg_ops.cfg_update(x, ec, eu, s, ab_t, ab_prev, z)
        assert jnp.array_equal(row, scal)


def test_cfg_fuse_rowwise_inactive_rows_frozen(rng_key):
    """active=0 rows pass through bit-unchanged (the right-aligned ragged
    freeze), in both the kernel and the oracle."""
    ks = jax.random.split(rng_key, 4)
    shape = (5, 8, 8, 3)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    act = jnp.array([1.0, 0.0, 1.0, 0.0, 0.0])
    s = jnp.full((5,), 7.5)
    ab_t, ab_prev = jnp.full((5,), 0.31), jnp.full((5,), 0.52)
    out = cfg_ops.cfg_update_rowwise(x, ec, eu, s, ab_t, ab_prev, z, act)
    ref = cfg_ref.cfg_update_rowwise(x, ec, eu, s, ab_t, ab_prev, z, act)
    for b, a in enumerate([1, 0, 1, 0, 0]):
        if a:
            assert not jnp.array_equal(out[b], x[b])
        else:
            assert jnp.array_equal(out[b], x[b])
            assert jnp.array_equal(ref[b], x[b])


@pytest.mark.parametrize("off,B,Bs", [(0, 4, 4), (0, 3, 8), (2, 3, 8),
                                      (5, 3, 8), (3, 5, 8)])
def test_cfg_fuse_rowwise_segment_offset(rng_key, off, B, Bs):
    """Segment-offset scalar-prefetch path: the per-row scalar table
    spans a full wave (Bs rows) while the launch updates a window of B
    tensor rows starting at ``row_offset`` — tensor row b must read
    scalar slot off+b, exactly the windowed oracle."""
    ks = jax.random.split(rng_key, 4)
    shape = (B, 8, 8, 3)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    s = jnp.linspace(0.0, 7.5, Bs)
    ab_t = jnp.linspace(0.05, 0.9, Bs)
    ab_prev = jnp.linspace(0.11, 0.95, Bs)
    act = (jnp.arange(Bs) % 2 == 0).astype(jnp.float32)
    out = cfg_ops.cfg_update_rowwise(x, ec, eu, s, ab_t, ab_prev, z, act,
                                     row_offset=off)
    ref = cfg_ref.cfg_update_rowwise_windowed(x, ec, eu, s, ab_t, ab_prev,
                                              z, act, row_offset=off)
    assert out.shape == shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-5
    # and the window is bit-equal to slicing the scalars up front — the
    # offset only changes addressing, never arithmetic
    w = slice(off, off + B)
    sliced = cfg_ops.cfg_update_rowwise(x, ec, eu, s[w], ab_t[w],
                                        ab_prev[w], z, act[w])
    assert jnp.array_equal(out, sliced)


def test_cfg_fuse_rowwise_offset_out_of_range_refuses(rng_key):
    """A window past the scalar table's rows must refuse loudly — serving
    row b with another row's (ᾱ, s) would corrupt a whole trajectory."""
    ks = jax.random.split(rng_key, 4)
    x, ec, eu, z = (jax.random.normal(k, (4, 8, 8, 3)) for k in ks)
    v = jnp.linspace(0.1, 0.9, 6)
    with pytest.raises(ValueError, match="out of range"):
        cfg_ops.cfg_update_rowwise(x, ec, eu, v, v, v, z, jnp.ones((6,)),
                                   row_offset=3)
    # negative offsets would silently wrap the scalar reads on CPU (and
    # are out-of-bounds UB on TPU) — refuse them the same way
    with pytest.raises(ValueError, match="out of range"):
        cfg_ops.cfg_update_rowwise(x, ec, eu, v, v, v, z, jnp.ones((6,)),
                                   row_offset=-2)


def test_cfg_fuse_rowwise_bf16(rng_key):
    """bf16 rows: f32 accumulation, one rounding on store — within one
    bf16 ulp of the f32 oracle, dtype preserved."""
    ks = jax.random.split(rng_key, 4)
    shape = (4, 16, 16, 3)
    x, ec, eu, z = (jax.random.normal(k, shape, jnp.bfloat16) for k in ks)
    s = jnp.linspace(0.0, 7.5, 4)
    ab_t = jnp.linspace(0.05, 0.9, 4)
    ab_prev = jnp.linspace(0.11, 0.95, 4)
    out = cfg_ops.cfg_update_rowwise(x, ec, eu, s, ab_t, ab_prev, z,
                                     jnp.ones((4,)))
    assert out.dtype == jnp.bfloat16
    ref = cfg_ref.cfg_update_rowwise(
        x.astype(jnp.float32), ec.astype(jnp.float32),
        eu.astype(jnp.float32), s, ab_t, ab_prev, z.astype(jnp.float32),
        jnp.ones((4,)))
    err = jnp.abs(out.astype(jnp.float32) - ref)
    assert bool(jnp.all(err <= 2.0 ** -8 * jnp.maximum(jnp.abs(ref), 1.0)))


@pytest.mark.parametrize("shape", [(4, 16, 16, 3), (300, 128)])
def test_cfg_fuse_bf16(rng_key, shape):
    """bf16 inputs: kernel accumulates in f32 and rounds once on store, so
    it must stay within one bf16 ulp of the f32 oracle."""
    ks = jax.random.split(rng_key, 4)
    x, ec, eu, z = (jax.random.normal(k, shape, jnp.bfloat16) for k in ks)
    out = cfg_ops.cfg_update(x, ec, eu, 7.5, 0.31, 0.52, z)
    assert out.dtype == jnp.bfloat16
    ref = cfg_ref.cfg_update(x.astype(jnp.float32), ec.astype(jnp.float32),
                             eu.astype(jnp.float32), 7.5, 0.31, 0.52,
                             z.astype(jnp.float32))
    # bound: one bf16 ulp of the f32 result (outputs reach ~±30 at s=7.5)
    err = jnp.abs(out.astype(jnp.float32) - ref)
    assert bool(jnp.all(err <= 2.0 ** -8 * jnp.maximum(jnp.abs(ref), 1.0)))


# ---------------------------------------------------------------------------
# mixed-guidance rowwise: per-row (mode, ᾱ_t, ᾱ_prev, s, active)
# ---------------------------------------------------------------------------

def _mixed_rows(B):
    mode = (jnp.arange(B) % 2).astype(jnp.float32)
    s = jnp.linspace(0.0, 7.5, B)
    ab_t = jnp.linspace(0.05, 0.9, B)
    ab_prev = jnp.linspace(0.11, 0.95, B)
    act = (jnp.arange(B) % 3 != 1).astype(jnp.float32)
    return mode, s, ab_t, ab_prev, act


@pytest.mark.parametrize("shape", [(6, 16, 16, 3), (3, 8, 8, 1), (5, 97, 13)])
def test_cfg_fuse_mixed_matches_oracle(rng_key, shape):
    """Mixed-guidance kernel: mode-0 rows combine (1+s)ε_c − sε_u, mode-1
    rows take ε_c as the upstream-corrected ε̂ — vs the rowwise jnp
    oracle, incl. non-lane-aligned per-image flatten."""
    B = shape[0]
    ks = jax.random.split(rng_key, 4)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    mode, s, ab_t, ab_prev, act = _mixed_rows(B)
    out = cfg_ops.cfg_update_mixed(x, ec, eu, mode, s, ab_t, ab_prev, z, act)
    ref = cfg_ref.cfg_update_mixed(x, ec, eu, mode, s, ab_t, ab_prev, z, act)
    assert out.shape == shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


def test_cfg_fuse_mixed_all_cfg_rows_match_rowwise_kernel(rng_key):
    """mode ≡ 0 must reproduce the pure cfg rowwise kernel BIT-exactly —
    the contract that lets the engine keep dispatching the pure
    executable for clf-free waves without a parity cliff."""
    ks = jax.random.split(rng_key, 4)
    shape = (4, 16, 16, 3)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    _, s, ab_t, ab_prev, act = _mixed_rows(4)
    out = cfg_ops.cfg_update_mixed(x, ec, eu, jnp.zeros((4,)), s, ab_t,
                                   ab_prev, z, act)
    pure = cfg_ops.cfg_update_rowwise(x, ec, eu, s, ab_t, ab_prev, z, act)
    assert jnp.array_equal(out, pure)


def test_cfg_fuse_mixed_mode1_ignores_s_and_eps_u(rng_key):
    """mode-1 rows carry an already-corrected ε̂ in the ε_c slot: their
    (s, ε_u) row values must be dead — bit-equal to a mode-0 row at
    s=0, whatever garbage sits in those slots."""
    ks = jax.random.split(rng_key, 5)
    shape = (4, 8, 8, 3)
    x, ec, eu, junk = (jax.random.normal(k, shape) for k in ks[:4])
    z = jax.random.normal(ks[4], shape)
    _, _, ab_t, ab_prev, _ = _mixed_rows(4)
    ones = jnp.ones((4,))
    clf = cfg_ops.cfg_update_mixed(x, ec, junk, ones, jnp.full((4,), 7.5),
                                   ab_t, ab_prev, z, ones)
    s0 = cfg_ops.cfg_update_mixed(x, ec, eu, jnp.zeros((4,)),
                                  jnp.zeros((4,)), ab_t, ab_prev, z, ones)
    assert jnp.array_equal(clf, s0)


@pytest.mark.parametrize("off,B,Bs", [(0, 4, 4), (2, 3, 8), (3, 5, 8)])
def test_cfg_fuse_mixed_segment_offset(rng_key, off, B, Bs):
    """Segment-offset scalar-prefetch path for mixed waves: the (5, Bs)
    scalar table spans the wave, tensor row b reads slot off+b — exactly
    the windowed oracle, and bit-equal to slicing the table up front."""
    ks = jax.random.split(rng_key, 4)
    shape = (B, 8, 8, 3)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    mode, s, ab_t, ab_prev, act = _mixed_rows(Bs)
    out = cfg_ops.cfg_update_mixed(x, ec, eu, mode, s, ab_t, ab_prev, z,
                                   act, row_offset=off)
    ref = cfg_ref.cfg_update_mixed_windowed(x, ec, eu, mode, s, ab_t,
                                            ab_prev, z, act, row_offset=off)
    assert out.shape == shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-5
    w = slice(off, off + B)
    sliced = cfg_ops.cfg_update_mixed(x, ec, eu, mode[w], s[w], ab_t[w],
                                      ab_prev[w], z, act[w])
    assert jnp.array_equal(out, sliced)


def test_cfg_fuse_mixed_offset_out_of_range_refuses(rng_key):
    """A window past the mixed scalar table must refuse loudly — a row
    reading another row's (mode, ᾱ, s) corrupts a whole trajectory."""
    ks = jax.random.split(rng_key, 4)
    x, ec, eu, z = (jax.random.normal(k, (4, 8, 8, 3)) for k in ks)
    v = jnp.linspace(0.1, 0.9, 6)
    m = jnp.zeros((6,))
    with pytest.raises(ValueError, match="out of range"):
        cfg_ops.cfg_update_mixed(x, ec, eu, m, v, v, v, z, jnp.ones((6,)),
                                 row_offset=3)
    with pytest.raises(ValueError, match="out of range"):
        cfg_ops.cfg_update_mixed(x, ec, eu, m, v, v, v, z, jnp.ones((6,)),
                                 row_offset=-2)


def test_cfg_fuse_mixed_inactive_rows_frozen(rng_key):
    """active=0 rows pass through bit-unchanged in BOTH modes — retired
    clf rows freeze exactly like retired cfg rows."""
    ks = jax.random.split(rng_key, 4)
    shape = (4, 8, 8, 3)
    x, ec, eu, z = (jax.random.normal(k, shape) for k in ks)
    mode = jnp.array([0.0, 1.0, 0.0, 1.0])
    act = jnp.array([1.0, 1.0, 0.0, 0.0])
    _, s, ab_t, ab_prev, _ = _mixed_rows(4)
    out = cfg_ops.cfg_update_mixed(x, ec, eu, mode, s, ab_t, ab_prev, z, act)
    for b, a in enumerate([1, 1, 0, 0]):
        if a:
            assert not jnp.array_equal(out[b], x[b])
        else:
            assert jnp.array_equal(out[b], x[b])


# ---------------------------------------------------------------------------
# non-causal S = n_tok + 1 (the DiT's prepended conditioning token)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,Hq,Hkv", [
    (65, 4, 4),     # 8x8 patch grid + cond token
    (65, 4, 2),     # ...with GQA
    (17, 4, 4),     # 4x4 patch grid + cond token
])
def test_flash_attention_noncausal_token_plus_one(rng_key, S, Hq, Hkv):
    """Encoder-mode attention at the DiT's odd sequence length: S=n_tok+1
    rounds the blocks up to the sublane multiple, so this shape MUST take
    the pad_q/pad_k path (padded K rows masked via true_sk).  Covers GQA
    and the softcap=0 branch explicitly."""
    blk = min(128, max(8, -(-S // 8) * 8))
    assert (-S) % blk, "shape no longer exercises the padding path"
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, S, Hq, 32))
    k = jax.random.normal(ks[1], (2, S, Hkv, 32))
    v = jax.random.normal(ks[2], (2, S, Hkv, 32))
    out = fa_ops.flash_attention(q, k, v, causal=False, softcap=0.0)
    ref = fa_ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=False,
                           softcap=0.0).transpose(0, 2, 1, 3)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


# ---------------------------------------------------------------------------
# fused adaLN LayerNorm (kernels/adaln_norm)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,dt", [
    ((2, 17, 128), jnp.float32),    # DiT wave: n_tok+1 (forces row pad)
    ((4, 65, 64), jnp.float32),
    ((3, 256, 128), jnp.float32),   # no padding needed
    ((2, 17, 128), jnp.bfloat16),
])
def test_adaln_norm_matches_oracle(rng_key, shape, dt):
    from repro.kernels.adaln_norm import ops as an_ops
    from repro.kernels.adaln_norm import ref as an_ref
    ks = jax.random.split(rng_key, 3)
    B, _, d = shape
    x = jax.random.normal(ks[0], shape, dt)
    scale = jax.random.normal(ks[1], (B, d), dt) * 0.5
    shift = jax.random.normal(ks[2], (B, d), dt) * 0.5
    out = an_ops.adaln_norm(x, scale, shift)
    assert out.dtype == dt
    ref = an_ref.adaln_norm(x, scale, shift)
    if dt == jnp.bfloat16:
        err = jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))
        assert bool(jnp.all(err <= 2.0 ** -8 *
                            jnp.maximum(jnp.abs(ref.astype(jnp.float32)),
                                        1.0)))
    else:
        assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_adaln_norm_matches_dit_inline_site(rng_key):
    """The kernel must reproduce the DiT's hand-rolled modulation site
    ``_ln(x)·(1+scale)+shift`` — the expression it replaces."""
    from repro.diffusion.dit import _ln
    from repro.kernels.adaln_norm import ops as an_ops
    ks = jax.random.split(rng_key, 3)
    x = jax.random.normal(ks[0], (3, 17, 64))
    scale = jax.random.normal(ks[1], (3, 64)) * 0.1
    shift = jax.random.normal(ks[2], (3, 64)) * 0.1
    inline = _ln(x) * (1 + scale[:, None]) + shift[:, None]
    out = an_ops.adaln_norm(x, scale, shift)
    assert jnp.max(jnp.abs(out - inline)) < 2e-6


def test_adaln_norm_per_row_modulation(rng_key):
    """Each batch row is modulated by ITS OWN (scale, shift): permuting
    the modulation rows must permute the outputs identically."""
    from repro.kernels.adaln_norm import ops as an_ops
    ks = jax.random.split(rng_key, 3)
    x = jax.random.normal(ks[0], (1, 24, 32))
    x3 = jnp.broadcast_to(x, (3, 24, 32))
    scale = jax.random.normal(ks[1], (3, 32))
    shift = jax.random.normal(ks[2], (3, 32))
    out = an_ops.adaln_norm(x3, scale, shift)
    perm = jnp.array([2, 0, 1])
    out_p = an_ops.adaln_norm(x3, scale[perm], shift[perm])
    assert jnp.array_equal(out[perm], out_p)
