"""Diffusion-stack invariants: schedule algebra, CFG semantics, sampler
shapes, classifier-guided path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.ddpm import diffusion_loss
from repro.diffusion.dit import dit_apply, init_dit
from repro.diffusion.sampler import (_respaced_ts, sample_cfg,
                                     sample_classifier_guided)
from repro.diffusion.schedule import make_schedule, q_sample

DC = DiffusionConfig(d_model=64, num_layers=2, num_heads=2,
                     sample_timesteps=8, train_timesteps=64)


@pytest.mark.parametrize("kind", ["linear", "cosine"])
def test_schedule_monotone_and_bounded(kind):
    s = make_schedule(128, kind)
    assert s.alpha_bar.shape == (128,)
    assert bool(jnp.all(jnp.diff(s.alpha_bar) <= 1e-7))
    assert bool(jnp.all(s.betas > 0)) and bool(jnp.all(s.betas < 1))
    assert jnp.allclose(s.sqrt_ab ** 2 + s.sqrt_1mab ** 2, 1.0, atol=1e-5)


@given(t=st.integers(0, 63))
@settings(max_examples=10, deadline=None)
def test_q_sample_snr_decreases(t):
    s = make_schedule(64, "cosine")
    key = jax.random.PRNGKey(0)
    x0 = jnp.ones((2, 8, 8, 3))
    noise = jax.random.normal(key, x0.shape)
    xt = q_sample(s, x0, jnp.array([t, t]), noise)
    # signal coefficient shrinks with t
    assert float(s.sqrt_ab[t]) <= float(s.sqrt_ab[0]) + 1e-6


def test_respaced_ts_cover_range():
    ts = _respaced_ts(1000, 50)
    assert ts.shape == (50,)
    assert int(ts[0]) == 999 and int(ts[-1]) == 0
    assert bool(jnp.all(jnp.diff(ts) < 0))


@pytest.mark.parametrize("T", [10, 16, 32, 100, 1000])
def test_respaced_ts_no_duplicate_timesteps(T):
    """Every trajectory is STRICTLY decreasing — a repeated t would waste
    a denoiser call re-noising in place — starts at T-1, and (for >=2
    steps) ends at 0."""
    for S in {1, 2, 3, T // 2, T - 1, T}:
        ts = np.asarray(_respaced_ts(T, S))
        assert len(np.unique(ts)) == S, (T, S)
        assert int(ts[0]) == T - 1
        assert bool(np.all(np.diff(ts) <= -1)) if S > 1 else True
        if S >= 2:
            assert int(ts[-1]) == 0


def test_respaced_ts_unchanged_where_collision_free():
    """The dedupe envelope is the identity on every historical (collision-
    free) trajectory — respacing stays bit-compatible with the seed."""
    for T, S in ((1000, 50), (64, 8), (32, 6), (16, 3), (100, 100)):
        old = np.asarray(jnp.linspace(T - 1, 0, S).round().astype(jnp.int32))
        assert np.array_equal(old, np.asarray(_respaced_ts(T, S)))


def test_respaced_ts_rejects_more_steps_than_T():
    """num_steps > T cannot visit distinct timesteps; rounding silently
    emitted duplicates before — now it refuses loudly."""
    with pytest.raises(ValueError, match="cannot"):
        _respaced_ts(16, 20)


@given(T=st.integers(2, 1000), frac=st.floats(0.001, 1.0))
@settings(max_examples=40, deadline=None)
def test_respaced_ts_invariants_fuzzed(T, frac):
    """Property: EVERY admissible (T, num_steps) yields a strictly
    decreasing trajectory from T-1 hitting 0 — the invariant the ragged
    tables (and therefore every compaction segment) inherit per row."""
    S = max(1, min(T, round(frac * T)))
    ts = np.asarray(_respaced_ts(T, S))
    assert ts.shape == (S,)
    assert int(ts[0]) == T - 1
    assert len(np.unique(ts)) == S                 # strictly decreasing
    if S > 1:
        assert bool(np.all(np.diff(ts) <= -1))
        assert int(ts[-1]) == 0
    assert bool(np.all((ts >= 0) & (ts < T)))


@given(T=st.integers(2, 64), extra=st.integers(1, 16))
@settings(max_examples=20, deadline=None)
def test_respaced_ts_rejects_oversubscription_fuzzed(T, extra):
    """Property: every num_steps > T refuses, at any scale."""
    with pytest.raises(ValueError, match="cannot"):
        _respaced_ts(T, T + extra)


def test_dedupe_envelope_on_crafted_collisions():
    from repro.diffusion.guidance import _strictly_decreasing
    ts = jnp.array([15, 14, 13, 13, 12, 5, 5, 5, 1, 0])
    fixed = np.asarray(_strictly_decreasing(ts, 10))
    assert bool(np.all(np.diff(fixed) <= -1))
    assert fixed[0] == 15 and fixed[-1] == 0
    # never above the input's running envelope, so order is preserved
    assert bool(np.all(fixed <= np.asarray(ts)))


def test_dit_shapes_and_null_cond(rng_key):
    p = init_dit(rng_key, DC, image_size=16, channels=3)
    x = jax.random.normal(rng_key, (2, 16, 16, 3))
    t = jnp.array([3, 5])
    y = jax.random.normal(rng_key, (2, DC.cond_dim))
    eps = dit_apply(p, DC, x, t, y)
    assert eps.shape == x.shape
    eps_null = dit_apply(p, DC, x, t, None)
    assert eps_null.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(eps)))


def test_diffusion_loss_finite_and_positive(rng_key):
    p = init_dit(rng_key, DC, 16, 3)
    s = make_schedule(DC.train_timesteps)
    x0 = jax.random.normal(rng_key, (4, 16, 16, 3))
    y = jax.random.normal(rng_key, (4, DC.cond_dim))
    loss = diffusion_loss(p, DC, s, x0, y, rng_key)
    assert jnp.isfinite(loss) and loss > 0


def test_sample_cfg_shape_range(rng_key):
    p = init_dit(rng_key, DC, 16, 3)
    s = make_schedule(DC.train_timesteps)
    y = jax.random.normal(rng_key, (3, DC.cond_dim))
    x = sample_cfg(p, DC, s, y, rng_key, image_size=16)
    assert x.shape == (3, 16, 16, 3)
    assert bool(jnp.all(jnp.abs(x) <= 1.0))


def test_sample_cfg_pallas_matches_ref_path(rng_key):
    p = init_dit(rng_key, DC, 16, 3)
    s = make_schedule(DC.train_timesteps)
    y = jax.random.normal(rng_key, (2, DC.cond_dim))
    a = sample_cfg(p, DC, s, y, rng_key, image_size=16, use_pallas=False)
    b = sample_cfg(p, DC, s, y, rng_key, image_size=16, use_pallas=True)
    assert jnp.max(jnp.abs(a - b)) < 1e-4


def test_classifier_guided_sampler_runs(rng_key):
    p = init_dit(rng_key, DC, 16, 3)
    s = make_schedule(DC.train_timesteps)

    def logprob(x, labels):
        # toy classifier: brightness-based
        score = jnp.mean(x, axis=(1, 2, 3))
        logits = jnp.stack([score, -score], -1)
        lp = jax.nn.log_softmax(logits)
        return jnp.take_along_axis(lp, labels[:, None], axis=-1)[:, 0]

    labels = jnp.array([0, 1])
    x = sample_classifier_guided(p, DC, s, logprob, labels, rng_key,
                                 image_size=16)
    assert x.shape == (2, 16, 16, 3)
    assert bool(jnp.all(jnp.isfinite(x)))


def test_guidance_zero_ignores_sign_of_uncond(rng_key):
    """At s=0, Eq. 8 reduces to the conditional score: sampling must not
    depend on the null embedding."""
    p = init_dit(rng_key, DC, 16, 3)
    s = make_schedule(DC.train_timesteps)
    y = jax.random.normal(rng_key, (2, DC.cond_dim))
    a = sample_cfg(p, DC, s, y, rng_key, image_size=16, guidance=0.0)
    p2 = dict(p, null_y=p["null_y"] + 10.0)
    b = sample_cfg(p2, DC, s, y, rng_key, image_size=16, guidance=0.0)
    assert jnp.max(jnp.abs(a - b)) < 1e-5
