"""Fused denoiser: ``dit_apply(use_pallas=True)`` parity gate.

The fused path swaps the DiT's attention einsum chain for the Pallas
flash-attention kernel and its three LN+modulation sites for
``kernels/adaln_norm`` (CPU runs both under interpret).  The gate has two
layers: (1) fp32 fused output matches the naive denoiser within a tight
float tolerance (online softmax reorders the accumulation, so bit
equality is not expected ACROSS the flag); (2) under ONE flag setting the
whole serving stack — grouped/ragged/compacted/multi-host, warm stores —
produces bit-identical D_syn regardless of packing and placement, because
every mode runs the same ``dit_apply`` and row noise is keyed by request
identity.

NOTE: params are perturbed away from ``init_dit`` everywhere — adaLN-zero
initialisation zeroes the modulation/gates/output head, which would make
the denoiser output identically 0 and the parity trivially vacuous.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import dit_apply, init_dit
from repro.diffusion.sampler import (sample_cfg, sample_cfg_compacted,
                                     sample_cfg_ragged, sample_cfg_window,
                                     sample_classifier_guided, sample_uncond)
from repro.diffusion.schedule import make_schedule
from repro.serve import SynthesisEngine, SynthesisService, SynthesisStore

TOL = 2e-5       # fp32 fused-vs-naive, single dit_apply call
TOL_E2E = 2e-4   # ...compounded over a multi-step reverse trajectory


def _perturb(params, seed=1, scale=0.05):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(treedef, [
        a + scale * jax.random.normal(k, a.shape, a.dtype)
        for a, k in zip(leaves, keys)])


def _setup(dc, image_size, B, seed=0, channels=3):
    key = jax.random.PRNGKey(seed)
    params = _perturb(init_dit(key, dc, image_size, channels))
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, image_size, image_size, channels))
    t = jax.random.randint(jax.random.fold_in(key, 2), (B,), 0,
                           dc.train_timesteps)
    y = jax.random.normal(jax.random.fold_in(key, 3), (B, dc.cond_dim))
    return params, x, t, y


# ---------------------------------------------------------------------------
# single-call fp32 parity across geometries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,image_size,patch,heads", [
    (2, 16, 4, 4),    # production shape: 4x4 grid, S=17
    (1, 16, 2, 2),    # 8x8 grid, S=65
    (3, 8, 2, 4),     # small image
    (2, 8, 4, 1),     # single head
])
def test_dit_fused_matches_reference(B, image_size, patch, heads):
    dc = DiffusionConfig(d_model=64, num_layers=2, num_heads=heads,
                         patch=patch)
    params, x, t, y = _setup(dc, image_size, B)
    ref = dit_apply(params, dc, x, t, y)
    out = dit_apply(params, dc, x, t, y, use_pallas=True)
    assert float(jnp.max(jnp.abs(ref))) > 1e-3, "vacuous parity"
    assert float(jnp.max(jnp.abs(out - ref))) < TOL


def test_dit_fused_null_embedding_broadcast():
    """y=None routes through the learned null embedding Ø on both paths."""
    dc = DiffusionConfig(d_model=64, num_layers=2, num_heads=4)
    params, x, t, _ = _setup(dc, 16, 3)
    ref = dit_apply(params, dc, x, t, None)
    out = dit_apply(params, dc, x, t, None, use_pallas=True)
    assert float(jnp.max(jnp.abs(ref))) > 1e-3, "vacuous parity"
    assert float(jnp.max(jnp.abs(out - ref))) < TOL


def test_dit_fused_dc_flag_matches_kwarg():
    """``dc.use_pallas=True`` and the kwarg select the same code path."""
    dc = DiffusionConfig(d_model=64, num_layers=1, num_heads=4)
    dcf = DiffusionConfig(d_model=64, num_layers=1, num_heads=4,
                          use_pallas=True)
    params, x, t, y = _setup(dc, 16, 2)
    a = dit_apply(params, dc, x, t, y, use_pallas=True)
    b = dit_apply(params, dcf, x, t, y)
    assert jnp.array_equal(a, b)


def test_dit_bf16_act_opt_in():
    """bf16 activations (fp32 accumulation) stay within bf16 tolerance of
    the fp32 reference — and the flag is inert without ``use_pallas``."""
    kw = dict(d_model=64, num_layers=2, num_heads=4)
    dc = DiffusionConfig(**kw)
    dcb = DiffusionConfig(**kw, use_pallas=True, bf16_act=True)
    dc_inert = DiffusionConfig(**kw, bf16_act=True)   # no use_pallas
    params, x, t, y = _setup(dc, 16, 2)
    ref = dit_apply(params, dc, x, t, y)
    out = dit_apply(params, dcb, x, t, y)
    assert out.dtype == ref.dtype == jnp.float32
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-2 * max(scale, 1.0)
    assert jnp.array_equal(dit_apply(params, dc_inert, x, t, y), ref)


@given(image_size=st.sampled_from([8, 16]), patch=st.sampled_from([2, 4]),
       heads=st.sampled_from([1, 2, 4]), B=st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_dit_fused_parity_fuzz(image_size, patch, heads, B):
    """Property: fused==naive (fp32, tight tol) over random geometry."""
    dc = DiffusionConfig(d_model=32, num_layers=1, num_heads=heads,
                         patch=patch)
    params, x, t, y = _setup(dc, image_size, B,
                             seed=7 * image_size + patch + heads + B)
    ref = dit_apply(params, dc, x, t, y)
    out = dit_apply(params, dc, x, t, y, use_pallas=True)
    assert float(jnp.max(jnp.abs(out - ref))) < TOL


# ---------------------------------------------------------------------------
# end-to-end: every reverse core under the flag
# ---------------------------------------------------------------------------

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8


def _dm(seed=0):
    params = _perturb(init_dit(jax.random.PRNGKey(seed), DC, H, 3))
    return params, make_schedule(DC.train_timesteps, DC.schedule)


def _wave(B=4, seed=0):
    key = jax.random.PRNGKey(100 + seed)
    y = jax.random.normal(key, (B, DC.cond_dim))
    row_keys = jax.random.split(jax.random.fold_in(key, 1), B)
    guidance = np.array([1.5, 7.5, 2.0, 4.0], np.float32)[:B]
    steps = np.array([1, 3, 2, 3], np.int32)[:B]
    return y, row_keys, guidance, steps


def test_reverse_uniform_fused_parity():
    params, sched = _dm()
    y, _, _, _ = _wave()
    key = jax.random.PRNGKey(5)
    naive = sample_cfg(params, DC, sched, y, key, image_size=H)
    fused = sample_cfg(params, DC, sched, y, key, image_size=H,
                       use_pallas=True)
    assert float(jnp.max(jnp.abs(fused - naive))) < TOL_E2E


def test_reverse_ragged_window_compacted_fused_parity():
    """Fused vs naive within float tolerance in the ragged core — and the
    three row-keyed wave modes (ragged / windowed / compacted) stay
    BIT-identical to each other under the fused flag."""
    params, sched = _dm()
    y, row_keys, guidance, steps = _wave()
    kw = dict(image_size=H)
    naive = sample_cfg_ragged(params, DC, sched, y, row_keys, guidance,
                              steps, **kw)
    fused = sample_cfg_ragged(params, DC, sched, y, row_keys, guidance,
                              steps, use_pallas=True, **kw)
    assert float(jnp.max(jnp.abs(fused - naive))) < TOL_E2E
    comp = sample_cfg_compacted(params, DC, sched, y, row_keys, guidance,
                                steps, use_pallas=True, **kw)
    assert jnp.array_equal(comp, fused)
    win = sample_cfg_window(params, DC, sched, y, row_keys, guidance,
                            steps, row_offset=0, use_pallas=True, **kw)
    assert jnp.array_equal(win, fused)


def test_reverse_uncond_and_clf_fused_parity():
    params, sched = _dm()
    key = jax.random.PRNGKey(9)
    nu = sample_uncond(params, DC, sched, 3, key, image_size=H)
    fu = sample_uncond(params, DC, sched, 3, key, image_size=H,
                       use_pallas=True)
    assert float(jnp.max(jnp.abs(fu - nu))) < TOL_E2E

    def logprob(x, labels):
        return -0.01 * jnp.sum(x ** 2, axis=(1, 2, 3))

    labels = jnp.zeros((3,), jnp.int32)
    nc = sample_classifier_guided(params, DC, sched, logprob, labels, key,
                                  image_size=H)
    fc = sample_classifier_guided(params, DC, sched, logprob, labels, key,
                                  image_size=H, use_pallas=True)
    assert float(jnp.max(jnp.abs(fc - nc))) < TOL_E2E


# ---------------------------------------------------------------------------
# serving stack: D_syn bit-invariance under one flag setting
# ---------------------------------------------------------------------------

def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


_SUBS = [(_enc(i), c, n, g, s) for i, (c, n, g, s) in enumerate([
    (0, 2, 7.5, 3), (1, 1, 1.5, 1), (2, 2, 4.0, 2), (0, 1, 2.0, 3)])]


def _run_engine(key, **kw):
    params, sched = _dm()
    kw.setdefault("image_size", H)
    kw.setdefault("wave_size", 8)
    eng = SynthesisEngine(params, DC, sched, **kw)
    rids = [eng.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in _SUBS]
    out = eng.run(key)
    return [np.asarray(out[r]) for r in rids]


@pytest.mark.parametrize("kw", [
    dict(compaction="full"),
    dict(ragged=True, hosts=2),
    dict(ragged=False, hosts=2),           # grouped, placed
    dict(compaction="full", hosts=4),
])
def test_engine_dsyn_bit_invariant_under_fused_flag(kw):
    """Acceptance: with ``use_pallas=True`` everywhere, D_syn is
    bit-identical across grouped/ragged/compacted/multi-host packings —
    and float-close to the naive engine."""
    key = jax.random.PRNGKey(77)
    oracle = _run_engine(key, ragged=True, use_pallas=True)
    naive = _run_engine(key, ragged=True)
    outs = _run_engine(key, use_pallas=True, **kw)
    for a, b, n in zip(oracle, outs, naive):
        assert np.array_equal(a, b)
        assert float(np.max(np.abs(a - n))) < TOL_E2E


def test_warm_store_crosses_fused_flag(tmp_path):
    """A store warmed by a FUSED drain replays bit-identically into a
    naive engine (stores hold bits; the flag only affects generation)."""
    params, sched = _dm()
    key = jax.random.PRNGKey(42)
    warm = SynthesisService(
        SynthesisEngine(params, DC, sched, image_size=H, wave_size=8,
                        ragged=True, use_pallas=True),
        store=SynthesisStore(str(tmp_path)))
    futs = [warm.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in _SUBS]
    outs = warm.gather(futs, key)
    cold = SynthesisService(
        SynthesisEngine(params, DC, sched, image_size=H, wave_size=8,
                        ragged=True),
        store=SynthesisStore(str(tmp_path)))
    fc = [cold.submit(e, c, n, guidance=g, num_steps=s)
          for e, c, n, g, s in _SUBS]
    got = cold.gather(fc, key)
    assert cold.stats["generated"] == 0, "warm store must skip sampling"
    for a, b in zip(outs, got):
        assert np.array_equal(a, b)
