"""LM data-pipeline substrate tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.data.lm import (copy_task_corpus, make_lm_dataset, markov_corpus,
                           pack_sequences)


def test_markov_deterministic_and_in_range():
    a = markov_corpus(128, 1000, seed=3)
    b = markov_corpus(128, 1000, seed=3)
    assert np.array_equal(a, b)
    assert a.min() >= 0 and a.max() < 128


def test_copy_task_has_repeats():
    c = copy_task_corpus(64, 1024, span=8, seed=0)
    # spans are emitted twice: positions [0:8] == [8:16]
    assert np.array_equal(c[:8], c[8:16])


@given(seq=st.integers(4, 64), n=st.integers(100, 2000))
@settings(max_examples=15, deadline=None)
def test_pack_exact_shape(seq, n):
    toks = np.arange(n, dtype=np.int32)
    rows = pack_sequences(toks, seq)
    assert rows.shape == (n // seq, seq)
    assert np.array_equal(rows.reshape(-1), toks[:(n // seq) * seq])


def test_batches_deterministic_and_complete():
    ds = make_lm_dataset(64, seq_len=16, n_tokens=4000, seed=1)
    b1 = [b["tokens"] for b in ds.batches(4, seed=7, epochs=1)]
    b2 = [b["tokens"] for b in ds.batches(4, seed=7, epochs=1)]
    assert all(np.array_equal(x, y) for x, y in zip(b1, b2))
    assert len(b1) == len(ds.rows) // 4


def test_markov_is_learnable_structure():
    """Bigram entropy is far below uniform — a model CAN learn it."""
    c = markov_corpus(32, 20_000, seed=0)
    joint = np.zeros((32, 32))
    np.add.at(joint, (c[:-1], c[1:]), 1)
    cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
    ent = -np.nansum(cond * np.log2(np.where(cond > 0, cond, np.nan)), axis=1)
    marg = joint.sum(1) / joint.sum()
    avg_ent = float((marg * ent).sum())
    assert avg_ent < 0.8 * np.log2(32)
