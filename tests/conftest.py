import os
import sys
from pathlib import Path

# NOTE: deliberately NO XLA_FLAGS device-count override here — tests must
# see the real single CPU device (the 512-device mesh is dry-run only).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
