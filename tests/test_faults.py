"""Fault tolerance across the serving stack (``serve/faults.py``).

Three contracts under test:

* DETERMINISTIC FAILOVER — with any (site × host × wave) fault schedule
  killing hosts mid-drain, D_syn is BIT-IDENTICAL to the fault-free
  single-host oracle and no request is lost: row noise is keyed by
  request identity, so a host loss is a placement change, not a
  resample.  Fuzzed over H ∈ {2, 4} × grouped/ragged/compacted.

* ZERO-LOSS RETRY — an exception mid-drain leaves every unserved
  request queued AND carries already-produced rows to the next ``run``:
  exception → re-drain → every admitted request delivered.

* GRACEFUL STORE DEGRADATION — transient I/O retries under policy, a
  corrupt shard is quarantined (crash-safe manifest-first ordering, the
  same discipline as the evict suite) and regenerated, and write
  failures degrade to re-flush instead of failing the drain.
"""
import json
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.schedule import make_schedule
from repro.serve import (AllHostsLostError, FaultInjector, HostLostError,
                         HostTopology, InjectedFaultError,
                         RequestFailedError, RetryPolicy, SynthesisEngine,
                         SynthesisError, SynthesisService, SynthesisStore,
                         TransientFaultError, UnservedRequestError,
                         is_transient)

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8

_DM = None


def _dm():
    global _DM
    if _DM is None:
        key = jax.random.PRNGKey(0)
        params = init_dit(key, DC, H, 3)
        leaves, treedef = jax.tree.flatten(params)
        keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
        params = jax.tree.unflatten(treedef, [
            a + 0.05 * jax.random.normal(k, a.shape, a.dtype)
            for a, k in zip(leaves, keys)])
        _DM = params, make_schedule(DC.train_timesteps, DC.schedule)
    return _DM


def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


def _engine(**kw):
    params, sched = _dm()
    kw.setdefault("image_size", H)
    kw.setdefault("wave_size", 8)
    kw.setdefault("granule", 1)
    kw.setdefault("cache", False)
    return SynthesisEngine(params, DC, sched, **kw)


def _mixed_requests(seed):
    rng = np.random.default_rng(seed)
    subs = []
    for i in range(int(rng.integers(2, 6))):
        subs.append((_enc(100 * seed + i), int(rng.integers(0, 3)),
                     int(rng.integers(1, 6)),
                     float(rng.choice([1.5, 4.0, 7.5])),
                     int(rng.integers(1, 4))))
    return subs


def _run(subs, key, **kw):
    eng = _engine(**kw)
    rids = [eng.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in subs]
    out = eng.run(key)
    assert sorted(out) == sorted(rids)          # zero loss, zero phantoms
    return [out[r] for r in rids], eng


# ---------------------------------------------------------------------------
# typed error hierarchy
# ---------------------------------------------------------------------------

def test_error_hierarchy_and_classifier():
    assert issubclass(TransientFaultError, SynthesisError)
    assert issubclass(InjectedFaultError, TransientFaultError)
    for cls in (HostLostError, AllHostsLostError, RequestFailedError,
                UnservedRequestError):
        assert issubclass(cls, SynthesisError)
    assert issubclass(SynthesisError, RuntimeError)
    # host loss is handled by failover, never retried
    assert not issubclass(HostLostError, TransientFaultError)
    assert is_transient(InjectedFaultError("scan"))
    assert is_transient(OSError("flaky disk"))
    assert not is_transient(FileNotFoundError("a miss, not a fault"))
    assert not is_transient(ValueError("permanent"))
    err = RequestFailedError("boom", rid=7)
    assert err.rid == 7
    lost = HostLostError(2, wave=5)
    assert (lost.host, lost.wave) == (2, 5)


# ---------------------------------------------------------------------------
# FaultInjector
# ---------------------------------------------------------------------------

def test_injector_schedule_fires_once_with_wildcards():
    fi = FaultInjector(schedule=[("scan", None, 1), ("window", 0, None)])
    fi.check("scan", host=3, wave=0)            # wave mismatch: no fire
    with pytest.raises(InjectedFaultError):
        fi.check("scan", host=3, wave=1)        # wildcard host matches
    fi.check("scan", host=3, wave=1)            # entry consumed: no re-fire
    with pytest.raises(HostLostError) as ei:
        fi.check("window", host=0, wave=9)      # wildcard wave matches
    assert ei.value.host == 0 and ei.value.wave == 9
    fi.check("window", host=0, wave=9)
    assert fi.pending == 0
    assert fi.fired == [("scan", 3, 1), ("window", 0, 9)]


def test_injector_probability_is_seeded_and_capped():
    def drill(seed):
        fi = FaultInjector(p=0.5, seed=seed)
        hits = []
        for i in range(40):
            try:
                fi.check("scan", host=0, wave=i)
                hits.append(0)
            except InjectedFaultError:
                hits.append(1)
        return hits
    assert drill(3) == drill(3)                 # same seed, same faults
    assert drill(3) != drill(4)                 # no global RNG
    capped = FaultInjector(p=1.0, seed=0, max_faults=2)
    fired = 0
    for i in range(10):
        try:
            capped.check("store.read")
        except InjectedFaultError:
            fired += 1
    assert fired == 2 and len(capped.fired) == 2


def test_injector_rejects_unknown_site_and_bad_p():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultInjector(schedule=[("warp-core", 0, 0)])
    with pytest.raises(ValueError, match="p="):
        FaultInjector(p=1.5)


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------

def test_retry_succeeds_after_transient_with_backoff_and_metrics():
    from repro.obs import MetricsRegistry
    sleeps = []
    pol = RetryPolicy(max_attempts=4, base_delay=0.01, multiplier=2.0,
                      max_delay=0.03, sleep=sleeps.append)
    m = MetricsRegistry()
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 4:
            raise InjectedFaultError("scan")
        return "ok"

    assert pol.run(flaky, metrics=m, site="device.scan") == "ok"
    # exponential, capped at max_delay — and delivered via the INJECTED
    # sleep: no wall-clock was touched
    assert sleeps == [0.01, 0.02, 0.03]
    assert m.get("retry.attempts", site="device.scan") == 3
    assert m.get("retry.exhausted", site="device.scan") == 0


def test_retry_permanent_raises_immediately():
    sleeps = []
    pol = RetryPolicy(max_attempts=5, sleep=sleeps.append)
    calls = []

    def broken():
        calls.append(1)
        raise ValueError("permanent")

    with pytest.raises(ValueError):
        pol.run(broken)
    assert len(calls) == 1 and sleeps == []


def test_retry_exhaustion_reraises_last_transient():
    from repro.obs import MetricsRegistry
    m = MetricsRegistry()
    pol = RetryPolicy(max_attempts=3, sleep=lambda d: None)
    calls = []

    def always():
        calls.append(1)
        raise InjectedFaultError("store.read")

    with pytest.raises(InjectedFaultError):
        pol.run(always, metrics=m, site="store.read")
    assert len(calls) == 3
    assert m.get("retry.exhausted", site="store.read") == 1


def test_retry_policy_validates():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="backoff"):
        RetryPolicy(multiplier=0.5)


# ---------------------------------------------------------------------------
# elastic HostTopology
# ---------------------------------------------------------------------------

def test_mark_failed_requotas_and_reroutes_over_survivors():
    t = HostTopology.simulated(4, granule=2)
    assert t.live_hosts == (0, 1, 2, 3)
    t2 = t.mark_failed(1)
    assert t2.live_hosts == (0, 2, 3) and t2.failed == {1}
    assert t.failed == frozenset()              # original untouched
    # dead host: zero quota; survivors re-split the whole wave
    q = t2.wave_quotas(12)
    assert q[1] == 0 and all(x >= 2 for x in (q[0], q[2], q[3]))
    assert sum(q) >= 12
    # ingress never routes to the dead host
    assert 1 not in {t2.assign(r) for r in range(20)}
    # idempotent; stats stay index-aligned
    assert t2.mark_failed(1) is t2
    assert t2.num_hosts == 4
    with pytest.raises(ValueError, match="out of range"):
        t2.mark_failed(9)
    # placement simply skips the dead host's zero rows
    from repro.serve import WavePlacement
    pl = WavePlacement.plan([4, 0, 4, 4], t2.granules)
    assert [w.host for w in pl.windows] == [0, 2, 3]


def test_all_hosts_lost_raises():
    t = HostTopology.simulated(2)
    t = t.mark_failed(0)
    with pytest.raises(AllHostsLostError):
        t.mark_failed(1)


def test_opt_in_does_not_resurrect_failed_hosts():
    """Re-threading the SAME fleet through opt_in (every entry point
    does) must keep the engine's degraded topology — a dead host only
    rejoins through an explicitly different topology."""
    eng = _engine(hosts=2)
    eng.topology = eng.topology.mark_failed(1)
    eng.metrics.inc("host.rows", 5, host=0)
    eng.opt_in(hosts=2)                         # same fleet, re-threaded
    assert eng.topology.failed == {1}
    assert eng.metrics.get("host.rows", host=0) == 5
    eng.set_topology(HostTopology.simulated(3, granule=1))  # a NEW fleet
    assert eng.topology.failed == frozenset()


# ---------------------------------------------------------------------------
# failover determinism (the tentpole acceptance gate)
# ---------------------------------------------------------------------------

def _schedule_for(seed, hosts):
    """A random fault schedule: kill up to hosts-1 hosts at random waves
    plus scan faults (wildcard host) at distinct waves — never enough
    matching entries to exhaust the 3-attempt retry."""
    rng = np.random.default_rng(1000 + seed)
    sched = []
    for hkill in rng.permutation(hosts)[:int(rng.integers(1, hosts))]:
        sched.append(("window", int(hkill), int(rng.integers(0, 3))))
    for wave in rng.permutation(4)[:int(rng.integers(0, 3))]:
        sched.append(("scan", None, int(wave)))
    return sched


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(min_value=0, max_value=5),
       hosts=st.sampled_from([2, 4]),
       mode=st.sampled_from(["grouped", "ragged", "compacted"]))
def test_fuzz_failover_bit_identical_to_fault_free(seed, hosts, mode):
    """Any (site × host × wave) fault schedule over H ∈ {2, 4} ×
    grouped/ragged/compacted: every request is served, bit-identical to
    the fault-free single-host ragged oracle."""
    kw = {"grouped": {}, "ragged": {"ragged": True},
          "compacted": {"compaction": "full"}}[mode]
    subs = _mixed_requests(seed)
    key = jax.random.PRNGKey(seed)
    oracle, _ = _run(subs, key, ragged=True)
    schedule = _schedule_for(seed, hosts)
    faulty, eng = _run(subs, key, hosts=hosts,
                       faults=FaultInjector(schedule=schedule), **kw)
    for a, b in zip(oracle, faulty):
        assert np.array_equal(a, b)
    kills = [s for s in schedule if s[0] == "window"]
    fired_kills = [f for f in eng.faults.fired if f[0] == "window"]
    assert eng.topology.failed == {f[1] for f in fired_kills}
    assert eng.metrics.get("fault.host_lost") == len(fired_kills)
    # survivor per-host sums still equal the globals
    s = eng.stats
    assert sum(p["rows"] + p["padded"] for p in s["per_host"]) \
        == s["scheduled_rows"]
    assert sum(p["rows"] for p in s["per_host"]) == s["generated"]
    if fired_kills:
        for f in fired_kills:
            assert s["per_host"][f[1]]["rows"] <= s["generated"]
        assert eng.metrics.get("hosts_live") == hosts - len(
            {f[1] for f in fired_kills})


def test_failover_with_seeded_probability_faults():
    """Probability-triggered faults (seeded, no global RNG) recover the
    same way.  The sequential drain reproduces the whole degraded run —
    which faults fired, in order — end to end.  Concurrent workers keep
    every per-check draw identity-keyed, but the ``max_faults`` cap is
    claimed by arrival order, so two runs may cap DIFFERENT candidate
    faults; the served bytes are bit-identical either way (failover
    requeues, never resamples)."""
    subs = _mixed_requests(11)
    key = jax.random.PRNGKey(11)
    oracle, _ = _run(subs, key, ragged=True)
    outs = []
    for _ in range(2):
        res, eng = _run(subs, key, hosts=2, ragged=True, workers=False,
                        faults=FaultInjector(p=0.2, seed=5, max_faults=1))
        outs.append((res, tuple(eng.faults.fired)))
    assert outs[0][1] == outs[1][1]
    assert outs[0][1]                    # the seed actually fired a fault
    for a, b in zip(oracle, outs[0][0]):
        assert np.array_equal(a, b)
    # concurrent drain: the fired identity may vary with interleaving,
    # but the cap holds and the output is still the fault-free oracle's
    res_w, eng_w = _run(subs, key, hosts=2, ragged=True,
                        faults=FaultInjector(p=0.2, seed=5, max_faults=1))
    assert len(eng_w.faults.fired) <= 1
    for a, b in zip(oracle, res_w):
        assert np.array_equal(a, b)


def test_failover_emits_host_failed_instant_on_host_track():
    from repro.obs import Tracer
    from repro.obs.trace import FakeClock
    tr = Tracer(enabled=True, clock=FakeClock(tick=1.0))
    subs = _mixed_requests(3)
    _, eng = _run(subs, jax.random.PRNGKey(3), hosts=2, ragged=True,
                  tracer=tr, faults=FaultInjector(
                      schedule=[("window", 1, 0)]))
    inst = [s for s in tr.spans if s.name == "host.failed"]
    assert len(inst) == 1
    assert inst[0].attrs["host"] == 1 and inst[0].attrs["wave"] == 0
    assert eng.metrics.get("failover.requeued_rows") > 0


def test_all_hosts_lost_propagates_and_requests_survive():
    """Killing every host is not recoverable — the drain raises
    AllHostsLostError — but no request is lost: they stay queued and a
    fresh topology serves them bit-identically."""
    subs = _mixed_requests(7)
    key = jax.random.PRNGKey(7)
    oracle, _ = _run(subs, key, ragged=True)
    eng = _engine(hosts=2, ragged=True, faults=FaultInjector(
        schedule=[("window", 0, None), ("window", 1, None)]))
    rids = [eng.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in subs]
    with pytest.raises(AllHostsLostError):
        eng.run(key)
    assert [r.rid for r in eng._queue] == rids   # nothing dropped
    assert eng.topology.failed == {0}            # second kill never landed
    out = eng.run(key)          # schedule spent: the survivor serves all
    for r, o in zip(rids, oracle):
        assert np.array_equal(out[r], o)


# ---------------------------------------------------------------------------
# zero-loss retry (the serve/synthesis.py mid-drain exception contract)
# ---------------------------------------------------------------------------

def test_exception_then_redrain_delivers_every_admitted_request():
    """Regression for the carried-results contract: a sampler exception
    AFTER earlier waves retired used to lose those requests for direct
    engine callers (run() removed them from the queue but the raised
    drain never returned their rows).  Now exception → re-drain delivers
    every admitted request, bit-identical to a clean run."""
    subs = [(_enc(200 + i), i % 3, 7, 4.0, 3) for i in range(4)]
    oracle, _ = _run(subs, jax.random.PRNGKey(5), ragged=True)

    eng = _engine(ragged=True)
    rids = [eng.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in subs]
    orig = eng._sample_wave_ragged
    calls = []

    def failing(*a, **kw):
        calls.append(1)
        if len(calls) == 3:          # waves 1–2 dispatched, wave 1 retired
            raise RuntimeError("sampler died mid-drain")
        return orig(*a, **kw)

    eng._sample_wave_ragged = failing
    with pytest.raises(RuntimeError, match="mid-drain"):
        eng.run(jax.random.PRNGKey(5))
    served_early = 4 - len(eng._queue)
    assert served_early >= 1         # at least one request left the queue
    out = eng.run(jax.random.PRNGKey(5))     # same drain key: exact replay
    assert sorted(out) == rids
    for r, o in zip(rids, oracle):
        assert np.array_equal(out[r], o)


def test_redrain_streams_carried_rows_through_on_result():
    """Rows carried over from a failed drain reach the NEXT drain's
    on_result hook — a service retrying its drain resolves the futures
    served by the failed attempt."""
    eng = _engine(ragged=True)
    svc = SynthesisService(eng, key=2)
    futs = [svc.submit(_enc(300 + i), 0, 7, num_steps=3) for i in range(4)]
    orig = eng._sample_wave_ragged
    calls = []

    def failing(*a, **kw):
        calls.append(1)
        if len(calls) == 3:
            raise RuntimeError("boom")
        return orig(*a, **kw)

    eng._sample_wave_ragged = failing
    # direct engine drain WITHOUT hooks: the failure path legacy callers
    # hit — futures are not resolved by it
    with pytest.raises(RuntimeError, match="boom"):
        eng.run(jax.random.PRNGKey(4))
    eng._sample_wave_ragged = orig
    outs = svc.gather(futs)                  # retry drain (with hooks)
    assert all(f.done() for f in futs)
    assert [o.shape[0] for o in outs] == [7, 7, 7, 7]


# ---------------------------------------------------------------------------
# store degradation
# ---------------------------------------------------------------------------

def _warm_store(tmp_path, seed=40, count=4):
    store = SynthesisStore(tmp_path / "dsyn")
    eng = _engine(cache=True, store=store)
    rid = eng.submit(_enc(seed), 0, count)
    out = eng.run(jax.random.PRNGKey(seed))[rid]
    ent, = store._manifest["entries"].values()
    key = (ent["key"]["encoding_sha1"], ent["key"]["guidance"],
           ent["key"]["steps"])
    return out, key


def test_store_transient_read_faults_retry_to_a_hit(tmp_path):
    out, key = _warm_store(tmp_path)
    store = SynthesisStore(tmp_path / "dsyn")
    store.faults = FaultInjector(schedule=[("store.read", None, None)])
    store.retry = RetryPolicy(sleep=lambda d: None)
    rows = store.get(key)
    assert np.array_equal(rows, out)
    assert store.metrics.get("retry.attempts", site="store.read") == 1
    assert store.metrics.get("store.quarantined") == 0


def test_store_exhausted_read_is_a_miss_not_a_quarantine(tmp_path):
    out, key = _warm_store(tmp_path)
    store = SynthesisStore(tmp_path / "dsyn")
    store.faults = FaultInjector(schedule=[("store.read", None, None)] * 3)
    store.retry = RetryPolicy(sleep=lambda d: None)
    assert store.get(key) is None
    assert store.metrics.get("retry.exhausted", site="store.read") == 1
    # the file may be fine (flaky media): left in place, served next time
    assert store.metrics.get("store.quarantined") == 0
    assert np.array_equal(SynthesisStore(tmp_path / "dsyn").get(key), out)


def test_corrupt_shard_quarantined_and_regenerated_not_raised(tmp_path):
    """The acceptance-criteria path: a corrupted shard is quarantined
    and REGENERATED — bit-identically — rather than raising."""
    out, key = _warm_store(tmp_path, seed=41)
    shard, = (tmp_path / "dsyn" / "shards").glob("*.npz")
    shard.write_bytes(b"\x00garbage npz")
    store = SynthesisStore(tmp_path / "dsyn")
    eng = _engine(cache=True, store=store)
    rid = eng.submit(_enc(41), 0, 4)
    regen = eng.run(jax.random.PRNGKey(41))[rid]
    assert np.array_equal(regen, out)
    assert store.metrics.get("store.quarantined") == 1
    assert (tmp_path / "dsyn" / "quarantine" / shard.name).exists()
    # the manifest healed: a cold handle serves the regenerated rows
    cold = SynthesisStore(tmp_path / "dsyn")
    assert np.array_equal(cold.get(key), out)
    assert (tmp_path / "dsyn" / "shards" / shard.name).exists()


def test_store_write_failures_degrade_and_reflush_heals(tmp_path):
    store = SynthesisStore(tmp_path / "dsyn")
    eng = _engine(cache=True, store=store,
                  faults=FaultInjector(
                      schedule=[("store.write", None, None)] * 3),
                  retry=RetryPolicy(sleep=lambda d: None))
    rid = eng.submit(_enc(42), 0, 4)
    out = eng.run(jax.random.PRNGKey(42))[rid]    # flush degrades, no raise
    assert eng.metrics.get("store.write_failures") == 1
    ent, = store._manifest["entries"].values()
    key = (ent["key"]["encoding_sha1"], ent["key"]["guidance"],
           ent["key"]["steps"])
    # a manifest entry without its shard reads as a miss, never a crash
    assert SynthesisStore(tmp_path / "dsyn").get(key) is None
    store.flush()                                 # faults exhausted: heals
    assert np.array_equal(SynthesisStore(tmp_path / "dsyn").get(key), out)


# ---------------------------------------------------------------------------
# quarantine crash ordering (PR 4 evict-suite style)
# ---------------------------------------------------------------------------

def test_quarantine_crash_between_manifest_and_move(tmp_path, monkeypatch):
    """Dying AFTER the manifest heal but BEFORE the file moves strands
    at worst an orphaned shard — the reopened store never references a
    missing or corrupt shard, and a re-put heals around the orphan."""
    out, key = _warm_store(tmp_path, seed=43)
    shard, = (tmp_path / "dsyn" / "shards").glob("*.npz")
    shard.write_bytes(b"garbage")
    store = SynthesisStore(tmp_path / "dsyn")

    real_replace = os.replace

    def dying_replace(src, dst, *a, **kw):
        # match only the move INTO quarantine/, not the tmp_path (whose
        # name also contains "quarantine" — it is this test's name)
        if os.path.basename(os.path.dirname(str(dst))) == "quarantine":
            raise RuntimeError("crashed between manifest write and move")
        return real_replace(src, dst, *a, **kw)

    monkeypatch.setattr(os, "replace", dying_replace)
    with pytest.raises(RuntimeError, match="crashed"):
        store.get(key)
    monkeypatch.undo()

    cold = SynthesisStore(tmp_path / "dsyn")
    assert len(cold) == 0                   # entry left the manifest FIRST
    assert cold.get(key) is None            # a miss, not an error
    # and the orphaned garbage heals on regeneration
    eng = _engine(cache=True, store=cold)
    rid = eng.submit(_enc(43), 0, 4)
    assert np.array_equal(eng.run(jax.random.PRNGKey(43))[rid], out)
    assert np.array_equal(SynthesisStore(tmp_path / "dsyn").get(key), out)


def test_quarantine_crash_before_manifest_write_loses_nothing(tmp_path,
                                                              monkeypatch):
    """Dying BEFORE the manifest rewrite leaves the on-disk store
    exactly as it was: the corrupt shard is still referenced, and the
    next reader detects and quarantines it again."""
    _, key = _warm_store(tmp_path, seed=44)
    shard, = (tmp_path / "dsyn" / "shards").glob("*.npz")
    shard.write_bytes(b"garbage")
    store = SynthesisStore(tmp_path / "dsyn")

    def dying_write():
        raise RuntimeError("crashed before manifest write")

    monkeypatch.setattr(store, "_write_manifest", dying_write)
    with pytest.raises(RuntimeError, match="before manifest"):
        store.get(key)
    monkeypatch.undo()

    disk = json.loads((tmp_path / "dsyn" / "manifest.json").read_text())
    assert len(disk["entries"]) == 1        # nothing torn on disk
    assert shard.exists()
    cold = SynthesisStore(tmp_path / "dsyn")
    assert cold.get(key) is None            # re-detected, re-quarantined
    assert cold.metrics.get("store.quarantined") == 1


def test_quarantine_tombstone_blocks_resurrection_by_flush(tmp_path):
    """A handle that quarantined a slug must not resurrect it when a
    concurrent handle's manifest still lists it — same tombstone
    discipline as evict."""
    _, key = _warm_store(tmp_path, seed=45)
    a = SynthesisStore(tmp_path / "dsyn")       # will quarantine
    b = SynthesisStore(tmp_path / "dsyn")       # concurrent writer
    shard, = (tmp_path / "dsyn" / "shards").glob("*.npz")
    slug = shard.stem
    shard.write_bytes(b"garbage")
    assert a.get(key) is None                   # quarantined
    # b, opened before the quarantine, still lists the slug: its flush
    # resurrects the (now dangling) entry on disk ...
    b.put((key[0], key[1], key[2] + 1), np.zeros((1, H, H, 3), np.float32))
    b.flush()
    disk = json.loads((tmp_path / "dsyn" / "manifest.json").read_text())
    assert slug in disk["entries"]
    # ... but a's tombstone refuses to merge it back on a's next rewrite
    a._write_manifest()
    cold = SynthesisStore(tmp_path / "dsyn")
    assert slug not in cold._manifest["entries"]
    assert len(cold) == 1                       # b's new key survives


# ---------------------------------------------------------------------------
# service-level typed-error delivery
# ---------------------------------------------------------------------------

def test_poisoned_tenant_isolated_and_gather_returns_exceptions():
    params, sched = _dm()
    eng = SynthesisEngine(params, DC, sched, image_size=H, wave_size=8,
                          granule=1)
    svc = SynthesisService(eng, key=6)
    good = svc.submit(_enc(500), 0, 3)

    def poisoned(x, t):
        raise ValueError("poisoned classifier closure")

    bad = svc.submit_classifier_guided(poisoned, 1, 2)
    also_good = svc.submit(_enc(501), 1, 3)
    res = svc.drain()                            # no raise
    assert sorted(res) == [good.rid, also_good.rid]
    err = bad.exception()
    assert isinstance(err, RequestFailedError) and err.rid == bad.rid
    assert isinstance(err.__cause__, ValueError)
    assert good.exception() is None
    with pytest.raises(RequestFailedError):
        bad.result()
    mixed = svc.gather([good, bad, also_good], return_exceptions=True)
    assert mixed[0].shape == (3, H, H, 3)
    assert isinstance(mixed[1], SynthesisError)
    assert mixed[2].shape == (3, H, H, 3)
    with pytest.raises(RequestFailedError):
        svc.gather([good, bad, also_good])
    assert eng.metrics.get("requests_failed") == 1


def test_unserved_future_raises_typed_error():
    params, sched = _dm()
    eng = SynthesisEngine(params, DC, sched, image_size=H, wave_size=8,
                          granule=1)
    svc = SynthesisService(eng, key=1)
    fut = svc.submit(_enc(502), 0, 2)
    eng.run(jax.random.PRNGKey(0))               # bypasses delivery hooks
    with pytest.raises(UnservedRequestError):
        fut.result()
