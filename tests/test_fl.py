"""FL-loop invariants + communication accounting (property-based where it
counts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.core import comm
from repro.core.fl import _local_sgd, _tree_mean, run_fl
from repro.data.federated import partition_label_skew
from repro.models.classifiers import classifier_param_count, init_classifier


# ---------------------------------------------------------------------------
# communication accounting (Table IV)
# ---------------------------------------------------------------------------

def test_upload_ordering_matches_paper():
    """OSCAR < FedDISC < FedCADO << FedAvg — the paper's Fig. 1 ordering."""
    clf = 175_066  # our scaled ResNet-18
    ups = {m: comm.upload_params(m, num_categories=10, clf_params=clf,
                                 rounds=10)
           for m in ("local", "fedavg", "fedcado", "feddisc", "oscar")}
    assert ups["local"] == 0
    assert ups["oscar"] < ups["feddisc"] < ups["fedcado"] < ups["fedavg"]


def test_oscar_upload_is_c_times_512():
    assert comm.upload_params("oscar", num_categories=60) == 60 * 512


def test_paper_scale_reduction_at_least_99pct():
    t4 = comm.paper_scale_table4()
    red = comm.reduction_vs_sota(t4["OSCAR"], t4)
    assert red >= 0.99  # the paper's headline claim


@given(C=st.integers(1, 300), enc=st.sampled_from([256, 512, 768]))
@settings(max_examples=25, deadline=None)
def test_oscar_upload_scales_linearly(C, enc):
    assert comm.upload_params("oscar", num_categories=C, enc_dim=enc) == C * enc


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------

@given(n=st.integers(30, 200), clients=st.integers(2, 8),
       alpha=st.floats(0.1, 10.0))
@settings(max_examples=20, deadline=None)
def test_label_skew_partition_is_exact(n, clients, alpha):
    labels = np.random.default_rng(0).integers(0, 5, size=n).astype(np.int32)
    idx = partition_label_skew(np.zeros((n, 1)), labels, clients, alpha)
    allidx = np.concatenate(idx)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n  # a partition: no loss, no dup


# ---------------------------------------------------------------------------
# FL dynamics
# ---------------------------------------------------------------------------

def _toy_data(key, R=3, n=24, C=3):
    ks = jax.random.split(key, 2)
    x = jax.random.normal(ks[0], (R, n, 8, 8, 3))
    y = jax.random.randint(ks[1], (R, n), 0, C)
    return x, y


def test_fedavg_identical_clients_equals_single_client(rng_key):
    """If all clients hold identical data and use identical keys, the
    FedAvg aggregate equals any single client's local model."""
    x, y = _toy_data(rng_key, R=1)
    x3 = jnp.tile(x, (3, 1, 1, 1, 1))
    y3 = jnp.tile(y, (3, 1))
    g = init_classifier(rng_key, "resnet18", 3)
    h = jax.tree.map(jnp.zeros_like, g)
    keys = jnp.stack([rng_key] * 3)
    from functools import partial
    local = jax.vmap(partial(_local_sgd, name="resnet18", steps=5, batch=8),
                     in_axes=(None, None, 0, 0, 0))
    locals_, _ = local(g, h, x3, y3, keys)
    mean = _tree_mean(locals_)
    for m, l0 in zip(jax.tree.leaves(mean),
                     jax.tree.leaves(jax.tree.map(lambda a: a[0], locals_))):
        assert jnp.allclose(m, l0, atol=1e-5)


def test_fedprox_pulls_towards_global(rng_key):
    """Large μ ⇒ local model stays closer to the global model."""
    x, y = _toy_data(rng_key, R=1)
    g = init_classifier(rng_key, "resnet18", 3)
    h = jax.tree.map(jnp.zeros_like, g)

    def dist(mu):
        p, _ = _local_sgd(g, h, x[0], y[0], rng_key, name="resnet18",
                          steps=10, batch=8, mu=mu)
        return sum(float(jnp.sum(jnp.square(a - b)))
                   for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(g)))

    assert dist(10.0) < dist(0.0)


def test_run_fl_improves_over_init(rng_key):
    from repro.configs.oscar import DataConfig
    from repro.data.federated import make_federated_data
    data = make_federated_data(DataConfig(num_categories=4,
                                          train_per_cat_dom=6,
                                          test_per_cat_dom=4, num_domains=3))
    # shrink to 3 clients
    _, metrics, uploads = run_fl(rng_key, data, rounds=3, local_steps=10)
    assert metrics["avg"] > 1.0 / 4 * 0.8   # above ~chance
    assert uploads > 0
