"""Bit-for-bit parity: each GuidanceStrategy routed through the unified
``reverse_sample`` core must reproduce the pre-refactor samplers exactly
at fixed seed.  The reference loops below are verbatim copies of the
seed-era ``diffusion/sampler.py`` (before the strategy refactor) — they
are the frozen numerical contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import dit_apply, init_dit
from repro.diffusion.guidance import (ClassifierFree, ClassifierGuided,
                                      Unconditional, reverse_sample)
from repro.diffusion.sampler import (sample_cfg, sample_classifier_guided,
                                     sample_uncond)
from repro.diffusion.schedule import make_schedule
from repro.kernels.cfg_fuse import ref as cfg_ref

DC = DiffusionConfig(d_model=64, num_layers=2, num_heads=2,
                     sample_timesteps=6, train_timesteps=32)


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = init_dit(key, DC, 16, 3)
    sched = make_schedule(DC.train_timesteps, DC.schedule)
    return params, sched


# ---------------------------------------------------------------------------
# pre-refactor reference loops (seed-era sampler.py, copied verbatim)
# ---------------------------------------------------------------------------

def _respaced_ts(T, num_steps):
    return jnp.linspace(T - 1, 0, num_steps).round().astype(jnp.int32)


def _ancestral_coeffs(sched, ts):
    ab_t = sched.alpha_bar[ts]
    ab_prev = jnp.concatenate([sched.alpha_bar[ts[1:]], jnp.ones((1,))])
    return ab_t, ab_prev


def seed_sample_cfg(params, dc, sched, y, key, *, image_size=16, channels=3,
                    num_steps=None, guidance=None, eta=1.0,
                    use_pallas=False):
    B = y.shape[0]
    H = image_size
    s = dc.guidance_scale if guidance is None else guidance
    num_steps = num_steps or dc.sample_timesteps
    ts = _respaced_ts(sched.T, num_steps)
    ab_t, ab_prev = _ancestral_coeffs(sched, ts)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, (B, H, H, channels))
    null = jnp.broadcast_to(params["null_y"], (B, dc.cond_dim))
    y2 = jnp.concatenate([y, null], axis=0)

    def step(carry, inp):
        x, key = carry
        t, abt, abp = inp
        key, kn = jax.random.split(key)
        x2 = jnp.concatenate([x, x], axis=0)
        t2 = jnp.full((2 * B,), t, jnp.int32)
        eps2 = dit_apply(params, dc, x2, t2, y2)
        eps_c, eps_u = eps2[:B], eps2[B:]
        noise = jax.random.normal(kn, x.shape) * (t > 0)
        if use_pallas:
            from repro.kernels.cfg_fuse import ops as cfg_ops
            x = cfg_ops.cfg_update(x, eps_c, eps_u, s, abt, abp, noise, eta)
        else:
            x = cfg_ref.cfg_update(x, eps_c, eps_u, s, abt, abp, noise, eta)
        return (x, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), (ts, ab_t, ab_prev))
    return jnp.clip(x, -1.0, 1.0)


def seed_sample_classifier_guided(params, dc, sched, clf_logprob_fn, labels,
                                  key, *, image_size=16, channels=3,
                                  num_steps=None, guidance=None, eta=1.0):
    B = labels.shape[0]
    H = image_size
    s = dc.guidance_scale if guidance is None else guidance
    num_steps = num_steps or dc.sample_timesteps
    ts = _respaced_ts(sched.T, num_steps)
    ab_t, ab_prev = _ancestral_coeffs(sched, ts)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, (B, H, H, channels))

    def step(carry, inp):
        x, key = carry
        t, abt, abp = inp
        key, kn = jax.random.split(key)
        tb = jnp.full((B,), t, jnp.int32)
        eps_u = dit_apply(params, dc, x, tb, None)
        sigma_t = jnp.sqrt(1.0 - abt)
        x0 = jnp.clip((x - jnp.sqrt(1 - abt) * eps_u) / jnp.sqrt(abt), -1, 1)
        grad = jax.grad(lambda z: jnp.sum(clf_logprob_fn(z, labels)))(x0)
        gnorm = jnp.sqrt(jnp.sum(grad ** 2, axis=(1, 2, 3), keepdims=True))
        grad = grad / jnp.maximum(gnorm, 1e-6)
        enorm = jnp.sqrt(jnp.mean(eps_u ** 2, axis=(1, 2, 3), keepdims=True))
        eps_hat = eps_u - s * sigma_t * grad * enorm
        noise = jax.random.normal(kn, x.shape) * (t > 0)
        x = cfg_ref.ancestral_step(x, eps_hat, abt, abp, noise, eta)
        return (x, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), (ts, ab_t, ab_prev))
    return jnp.clip(x, -1.0, 1.0)


# ---------------------------------------------------------------------------
# parity assertions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("guidance", [None, 0.0, 7.5])
def test_cfg_strategy_bit_exact(setup, use_pallas, guidance):
    params, sched = setup
    key = jax.random.PRNGKey(3)
    y = jax.random.normal(jax.random.PRNGKey(4), (3, DC.cond_dim))
    ref = seed_sample_cfg(params, DC, sched, y, key, guidance=guidance,
                          use_pallas=use_pallas)
    new = sample_cfg(params, DC, sched, y, key, image_size=16,
                     guidance=guidance, use_pallas=use_pallas)
    assert np.array_equal(np.asarray(ref), np.asarray(new))


def test_classifier_guided_strategy_bit_exact(setup):
    params, sched = setup

    def logprob(x, labels):
        # smooth stand-in classifier: label-dependent quadratic score
        mu = (labels[:, None, None, None].astype(jnp.float32) - 1.0) / 2.0
        return -jnp.sum((x - mu) ** 2, axis=(1, 2, 3))

    key = jax.random.PRNGKey(5)
    labels = jnp.array([0, 1, 2], jnp.int32)
    ref = seed_sample_classifier_guided(params, DC, sched, logprob, labels,
                                        key)
    new = sample_classifier_guided(params, DC, sched, logprob, labels, key,
                                   image_size=16)
    assert np.array_equal(np.asarray(ref), np.asarray(new))


def test_uncond_strategy_is_null_conditioned_ancestral(setup):
    """Unconditional == the seed classifier-guided loop at s=0 (the guided
    term vanishes and only the null-conditioned score remains)."""
    params, sched = setup
    key = jax.random.PRNGKey(6)
    labels = jnp.array([0, 0], jnp.int32)
    ref = seed_sample_classifier_guided(
        params, DC, sched, lambda x, l: jnp.zeros((x.shape[0],)), labels,
        key, guidance=0.0)
    new = sample_uncond(params, DC, sched, 2, key, image_size=16)
    assert np.allclose(np.asarray(ref), np.asarray(new), atol=1e-6)


def test_reverse_sample_strategies_direct(setup):
    """The core accepts strategy objects directly (engine-style use)."""
    params, sched = setup
    key = jax.random.PRNGKey(7)
    y = jax.random.normal(jax.random.PRNGKey(8), (2, DC.cond_dim))
    via_wrapper = sample_cfg(params, DC, sched, y, key, image_size=16,
                             guidance=2.0)
    via_core = reverse_sample(params, DC, sched,
                              ClassifierFree(y=y, scale=2.0), key,
                              image_size=16)
    assert np.array_equal(np.asarray(via_wrapper), np.asarray(via_core))

    assert Unconditional(num=4).batch() == 4
    assert ClassifierGuided(logprob_fn=None, labels=np.zeros((3,)),
                            scale=1.0).batch() == 3
