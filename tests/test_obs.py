"""Observability layer: span tracing, metrics registry, Chrome trace
export — and the gate that tracing never changes a single D_syn bit."""
import json

import jax
import numpy as np
import pytest

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.schedule import make_schedule
from repro.obs import (LIFECYCLE_STAGES, FakeClock, Histogram,
                       MetricsRegistry, NULL_SPAN, Tracer, chrome_trace,
                       validate_chrome_trace, write_trace)
from repro.serve.service import SynthesisService
from repro.serve.synthesis import SynthesisEngine

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8


@pytest.fixture(scope="module")
def dm():
    key = jax.random.PRNGKey(0)
    params = init_dit(key, DC, H, 3)
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
    params = jax.tree.unflatten(treedef, [
        a + 0.05 * jax.random.normal(k, a.shape, a.dtype)
        for a, k in zip(leaves, keys)])
    sched = make_schedule(DC.train_timesteps, DC.schedule)
    return params, sched


def _engine(dm, **kw):
    params, sched = dm
    kw.setdefault("image_size", H)
    kw.setdefault("wave_size", 8)
    return SynthesisEngine(params, DC, sched, **kw)


def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


# ---------------------------------------------------------------- tracer --

def test_span_nesting_attrs_and_fake_clock():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("outer", host=1):
        clk.advance(1.0)
        with tr.span("inner", wave=3) as sp:
            clk.advance(0.25)
            sp.set(rows=64)
        clk.advance(0.5)
    # spans record on exit: inner closes first
    inner, outer = tr.spans
    assert inner.name == "inner" and inner.depth == 1
    assert inner.start == 1.0 and inner.duration == 0.25
    assert inner.attrs == {"wave": 3, "rows": 64}
    assert outer.name == "outer" and outer.depth == 0
    assert outer.start == 0.0 and outer.duration == 1.75
    assert outer.attrs == {"host": 1} and outer.end == 1.75


def test_span_records_on_exception():
    tr = Tracer(clock=FakeClock(tick=1.0))
    with pytest.raises(RuntimeError):
        with tr.span("doomed"):
            raise RuntimeError("boom")
    assert [s.name for s in tr.spans] == ["doomed"]
    assert not tr._stack                       # stack unwound cleanly


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    assert tr.span("x", a=1) is NULL_SPAN      # one shared object, no alloc
    assert tr.span("y") is NULL_SPAN
    with tr.span("z") as sp:
        sp.set(ignored=True)
    tr.instant("m")
    tr.stamp(7, "admit")
    assert tr.spans == [] and tr.lifecycle == {}


def test_lifecycle_stamps_first_wins_and_latency():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    for t, stage in enumerate(LIFECYCLE_STAGES):
        clk.advance(1.0)
        tr.stamp(0, stage)
    tr.stamp(0, "pack")                        # second pack is ignored
    assert tr.lifecycle[0]["pack"] == 3.0
    lat = tr.request_latency(0)
    assert lat["queue_wait"] == 2.0            # enqueue@2 → dispatch@4
    assert lat["e2e_latency"] == 5.0           # admit@1 → deliver@6
    assert tr.request_latency(99) == {}
    with pytest.raises(ValueError):
        tr.stamp(0, "not-a-stage")


# --------------------------------------------------------------- metrics --

def test_histogram_quantiles_vs_numpy_oracle():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-4.0, sigma=1.5, size=5000)
    h = Histogram()
    for v in vals:
        h.observe(v)
    assert h.count == len(vals)
    assert h.min == vals.min() and h.max == vals.max()
    np.testing.assert_allclose(h.sum, vals.sum(), rtol=1e-9)
    for q in (0.5, 0.9, 0.99):
        oracle = np.quantile(vals, q)
        # geometric buckets at 8/decade: estimate within ~33 % relative
        assert abs(h.quantile(q) - oracle) / oracle < 0.35, (q, oracle)
    p = h.percentiles()
    assert p["p50"] <= p["p90"] <= p["p99"] <= h.max


def test_histogram_edge_cases():
    h = Histogram(buckets=(1.0, 2.0, 4.0))
    assert np.isnan(h.quantile(0.5))           # empty
    h.observe(0.5)                             # underflow bucket
    h.observe(100.0)                           # overflow bucket
    assert h.quantile(0.0) >= h.min and h.quantile(1.0) <= h.max
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram(buckets=(2.0, 1.0))          # non-increasing edges


def test_registry_labels_drop_and_dump():
    m = MetricsRegistry()
    m.inc("host.rows", 5, host=0)
    m.inc("host.rows", 7, host=1)
    m.set_gauge("hosts", 2)
    m.observe("lat", 0.5)
    assert m.get("host.rows", host=0) == 5
    assert m.get("host.rows", host=1) == 7
    assert m.get("absent") == 0 and m.get("absent", default=None) is None
    d = m.as_dict()
    assert d["host.rows{host=0}"] == 5 and d["hosts"] == 2
    assert d["lat"]["count"] == 1 and d["lat"]["p50"] == 0.5
    m.drop("host.")
    assert m.get("host.rows", host=0) == 0
    assert m.get("hosts") == 2                 # prefix match, not substring
    with pytest.raises(TypeError):
        m.inc("hosts")                         # gauge used as counter


# ---------------------------------------------------------------- export --

def _traced_drain(dm, **kw):
    tr = Tracer()
    eng = _engine(dm, tracer=tr, **kw)
    rids = [eng.submit(_enc(i), i % 3, c) for i, c in enumerate((3, 5, 2, 6))]
    out = eng.run(jax.random.PRNGKey(1))
    return tr, eng, [out[r] for r in rids]


def test_chrome_trace_export_and_validation(dm, tmp_path):
    tr, eng, _ = _traced_drain(dm, hosts=2)
    path = tmp_path / "trace.json"
    obj = write_trace(path, tr, registry=eng.metrics, hosts=2)
    assert validate_chrome_trace(obj, require_hosts=2) > 0
    on_disk = json.loads(path.read_text())
    tracks = {e["args"]["name"] for e in on_disk["traceEvents"]
              if e.get("name") == "thread_name"}
    assert {"scheduler", "host 0", "host 1"} <= tracks
    spans = [e for e in on_disk["traceEvents"] if e["ph"] == "X"]
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in spans)
    # per-window spans carry the host onto that host's track
    host_tids = {e["tid"] for e in spans if e["name"] == "window.pack"}
    assert len(host_tids) == 2
    assert on_disk["metrics"]["requests"] == 4


def test_validate_rejects_malformed_traces():
    with pytest.raises(ValueError, match="no traceEvents"):
        validate_chrome_trace({})
    no_spans = {"traceEvents": [{"ph": "M", "ts": 0, "pid": 0, "tid": 0,
                                 "name": "process_name", "args": {}}]}
    with pytest.raises(ValueError, match="no complete"):
        validate_chrome_trace(no_spans)
    bad = {"traceEvents": [{"ph": "X", "ts": 1, "pid": 0, "tid": 0,
                            "name": "s", "dur": -5}]}
    with pytest.raises(ValueError, match="negative"):
        validate_chrome_trace(bad)
    missing = {"traceEvents": [{"ph": "X", "ts": 1, "dur": 1, "name": "s"}]}
    with pytest.raises(ValueError, match="missing"):
        validate_chrome_trace(missing)
    ok = {"traceEvents": [{"ph": "X", "ts": 1, "pid": 0, "tid": 0,
                           "name": "s", "dur": 1}]}
    with pytest.raises(ValueError, match="missing host tracks"):
        validate_chrome_trace(ok, require_hosts=1)


# ----------------------------------------------------- engine integration --

MODES = [dict(), dict(ragged=True), dict(compaction="full"),
         dict(hosts=2), dict(compaction="full", hosts=2)]


@pytest.mark.parametrize("kw", MODES,
                         ids=["grouped", "ragged", "compacted", "placed",
                              "placed_compacted"])
def test_dsyn_bit_identical_tracing_on_vs_off(dm, kw):
    """The determinism gate: spans and stamps observe the drain — they
    must never key noise, schedule waves, or order anything."""
    _, eng_off, out_off = (None, *(_traced_drain(dm, **kw)[1:]))
    eng_off2 = _engine(dm, **kw)               # untraced control
    rids = [eng_off2.submit(_enc(i), i % 3, c)
            for i, c in enumerate((3, 5, 2, 6))]
    out_plain = eng_off2.run(jax.random.PRNGKey(1))
    for traced, plain in zip(out_off, (out_plain[r] for r in rids)):
        assert np.array_equal(traced, plain)
    assert eng_off.stats == eng_off2.stats


@pytest.mark.parametrize("kw", MODES[:4],
                         ids=["grouped", "ragged", "compacted", "placed"])
def test_stats_view_backward_compatible(dm, kw):
    """The legacy ``stats`` dict view must keep every pre-registry key
    (including the per-host breakdown) with identical values."""
    eng = _engine(dm, **kw)
    for i, c in enumerate((3, 5, 2, 6)):
        eng.submit(_enc(i), i % 3, c)
    eng.run(jax.random.PRNGKey(1))
    s = eng.stats
    for key in ("requests", "waves", "generated", "scheduled_rows",
                "padded", "cache_hits",
                "store_hits", "streamed", "merged_waves", "compiled_shapes",
                "segments", "row_iters_scheduled", "row_iters_active"):
        assert key in s, key
    assert s["requests"] == 4 and s["generated"] == 16
    assert s["scheduled_rows"] == s["generated"] + s["padded"]
    if "hosts" in kw:
        assert s["hosts"] == kw["hosts"]
        assert len(s["per_host"]) == kw["hosts"]
        for p in s["per_host"]:
            assert set(p) == {"rows", "padded", "waves",
                              "row_iters_scheduled", "row_iters_active",
                              "queue_depth_at_start"}
        assert sum(p["rows"] + p["padded"] for p in s["per_host"]) \
            == s["scheduled_rows"]
        assert sum(p["rows"] for p in s["per_host"]) == s["generated"]


def test_engine_lifecycle_stamps_ordered(dm):
    tr, _, _ = _traced_drain(dm)
    for rid, stages in tr.lifecycle.items():
        assert set(stages) == set(LIFECYCLE_STAGES), rid
        times = [stages[st] for st in LIFECYCLE_STAGES]
        assert times == sorted(times), (rid, stages)


def test_service_latency_histograms(dm):
    eng = _engine(dm)
    svc = SynthesisService(eng, key=0, tracer=Tracer())
    futs = [svc.submit(_enc(i), i % 3, 4) for i in range(3)]
    svc.gather(futs)
    e2e = eng.metrics.get("request.e2e_latency", default=None)
    qw = eng.metrics.get("request.queue_wait", default=None)
    assert e2e["count"] == 3 and qw["count"] == 3
    assert e2e["p50"] <= e2e["p99"] and e2e["min"] > 0
    assert all(qw["min"] <= v <= e2e["max"] for v in (qw["p50"], qw["p99"]))
    svc.gather(futs)                           # resolved: no double count
    assert eng.metrics.get("request.e2e_latency", default=None)["count"] == 3
    assert "latency" in svc.stats


# ---------------------------------------------------------------------------
# thread-safety: drain workers hammer one registry / tracer
# ---------------------------------------------------------------------------

def test_metrics_and_tracer_hammer_no_lost_records():
    """N threads × M ops against one MetricsRegistry and one enabled
    Tracer: every increment, span, and stamp lands — the per-host drain
    workers mutate these concurrently, and a torn buffer append or a
    lost counter bump would silently corrupt stats."""
    import threading

    m = MetricsRegistry()
    tr = Tracer(clock=FakeClock(tick=0.001))
    N, M = 8, 300
    start = threading.Barrier(N)

    def worker(tid):
        start.wait()
        for i in range(M):
            m.inc("hits")
            m.inc("host.rows", 2, host=tid)
            m.observe("lat", float(i % 7))
            with tr.span("work", host=tid, i=i):
                tr.stamp(tid * M + i, "admit")
            tr.stamp(tid * M + i, "deliver")

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(N)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.get("hits") == N * M
    for tid in range(N):
        assert m.get("host.rows", host=tid) == 2 * M
    assert m.get("lat", default=None)["count"] == N * M
    spans = [s for s in tr.spans if s.name == "work"]
    assert len(spans) == N * M
    assert len(tr.lifecycle) == N * M
    assert all(set(st) == {"admit", "deliver"}
               for st in tr.lifecycle.values())
    # per-thread nesting: every span opened at depth 0 of its own stack
    assert all(s.depth == 0 for s in spans)


def test_disabled_tracer_stays_nullspan_under_threads():
    """The disabled fast path records nothing and allocates nothing:
    every thread gets the one shared NULL_SPAN and no clock is read."""
    import threading

    reads = []
    tr = Tracer(clock=lambda: reads.append(1) or 0.0, enabled=False)

    def worker():
        for i in range(200):
            assert tr.span("x", i=i) is NULL_SPAN
            tr.stamp(i, "admit")

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not reads and not tr.spans and not tr.lifecycle
