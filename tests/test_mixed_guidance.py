"""Mixed-guidance ragged waves: ONE scheduler for every guidance mode.

The tentpole contract: cfg, classifier-guided, and unconditional
requests merge into the same ragged/compacted/placed waves, and every
row's output is BIT-IDENTICAL to the same merged engine serving that
row's mode in isolation — for any host count, packing, arrival order,
or fault schedule.  Uncond rows ride pure cfg waves as s=0 null-cond
rows (no legacy grouped-uncond waves); classifier-guided rows carry a
per-row slot into the engine's classifier-ensemble registry and their
ε̂-correction batches the classifier over the wave without coupling
rows (per-sample classifier contract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.schedule import make_schedule
from repro.serve import SynthesisEngine, SynthesisStore
from repro.serve.faults import FaultInjector, RequestFailedError

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8

_DM = None


def _dm():
    global _DM
    if _DM is None:
        key = jax.random.PRNGKey(0)
        params = init_dit(key, DC, H, 3)
        leaves, treedef = jax.tree.flatten(params)
        keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
        params = jax.tree.unflatten(treedef, [
            a + 0.05 * jax.random.normal(k, a.shape, a.dtype)
            for a, k in zip(leaves, keys)])
        _DM = params, make_schedule(DC.train_timesteps, DC.schedule)
    return _DM


def _engine(**kw):
    params, sched = _dm()
    kw.setdefault("image_size", H)
    kw.setdefault("wave_size", 8)
    kw.setdefault("ragged", True)
    kw.setdefault("cache", False)
    return SynthesisEngine(params, DC, sched, **kw)


def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


# module-level classifier closures: stable identity → stable ensemble
# tuples → the jitted mixed executables are shared across engines/tests
def _lp_sq(x, labels):
    return -jnp.sum(x ** 2, axis=(1, 2, 3))


def _lp_shift(x, labels):
    pull = labels.astype(x.dtype)[:, None, None, None]
    return -jnp.sum((x - 0.3) ** 2, axis=(1, 2, 3)) \
        + 0.1 * jnp.sum(x * pull, axis=(1, 2, 3))


def _random_subs(rng, n):
    """n submission thunks covering random modes/guidances/steps; each
    replays identically against any engine (the isolated-oracle trick)."""
    subs = []
    for i in range(n):
        mode = ["cfg", "clf", "uncond"][int(rng.integers(0, 3))]
        count = int(rng.integers(1, 5))
        steps = int(rng.integers(1, 4))
        if mode == "cfg":
            e = _enc(int(rng.integers(0, 100)))
            g = float(rng.choice([1.5, 3.0, 7.5]))
            subs.append(lambda eng, e=e, c=count, g=g, s=steps:
                        eng.submit(e, 0, c, guidance=g, num_steps=s))
        elif mode == "clf":
            fn = (_lp_sq, _lp_shift)[int(rng.integers(0, 2))]
            cat = int(rng.integers(0, 3))
            g = float(rng.choice([1.0, 2.0]))
            subs.append(lambda eng, f=fn, cat=cat, c=count, g=g, s=steps,
                        i=i: eng.submit_classifier_guided(
                            f, cat, c, guidance=g, num_steps=s,
                            group=("cl", i)))
        else:
            cat = int(rng.integers(0, 3))
            subs.append(lambda eng, c=count, cat=cat, s=steps:
                        eng.submit_unconditional(c, category=cat,
                                                 num_steps=s))
    return subs


# one scheduler config per fuzz example, cycled by seed: every merged
# geometry (ragged / compacted / placed / placed+compacted) and a
# mid-drain host kill each get exercised across the example budget
_CONFIGS = [
    dict(),
    dict(compaction="full"),
    dict(hosts=2),
    dict(hosts=4, compaction="full"),
    dict(hosts=2,
         faults=lambda: FaultInjector(schedule=[("window", 0, 0)])),
    dict(hosts=3, compaction="full",
         faults=lambda: FaultInjector(schedule=[("window", 1, 1)])),
]


@given(seed=st.integers(0, 29))
@settings(max_examples=6, deadline=None)
def test_mixed_drains_match_isolated_mode_oracles_fuzzed(seed):
    """Property: for ANY mixed request set, scheduler geometry, and
    fault schedule, every request's rows are bit-identical to a plain
    single-host merged engine serving ONLY that request (rid-aligned) —
    and under a topology the per-host row/padding sums equal the global
    counters."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    subs = _random_subs(rng, int(rng.integers(2, 5)))
    conf = dict(_CONFIGS[seed % len(_CONFIGS)])
    if "faults" in conf:
        conf["faults"] = conf["faults"]()
    eng = _engine(**conf)
    rids = [sub(eng) for sub in subs]
    out = eng.run(key)
    for rid, sub in zip(rids, subs):
        solo = _engine()
        solo._next_rid = rid                     # align the row identity
        srid = sub(solo)
        assert np.array_equal(out[rid], solo.run(key)[srid]), \
            f"seed={seed} rid={rid} diverged from its isolated oracle"
    if eng.topology is not None:
        s = eng.stats
        assert sum(p["rows"] for p in s["per_host"]) == s["generated"]
        assert sum(p["rows"] + p["padded"] for p in s["per_host"]) \
            == s["scheduled_rows"]
        assert s["scheduled_rows"] == s["generated"] + s["padded"]


def test_uncond_rides_pure_cfg_ragged_waves():
    """Satellite: uncond rows are the s=0 null-cond degenerate point of
    the cfg combine — a cfg+uncond workload shares ONE merged wave on
    the PURE cfg executable (no mixed variant, no legacy grouped-uncond
    wave is ever dispatched)."""
    eng = _engine()
    rc = eng.submit(_enc(60), 0, 3, guidance=7.5, num_steps=3)
    ru = eng.submit_unconditional(4, num_steps=2)
    out = eng.run(jax.random.PRNGKey(7))
    assert out[rc].shape == (3, H, H, 3) and out[ru].shape == (4, H, H, 3)
    assert eng.stats["waves"] == eng.stats["merged_waves"] == 1
    assert {s[0] for s in eng.traj_shapes} == {"cfg-ragged"}, \
        eng.traj_shapes


def test_clf_requests_have_real_rids_and_survive_clf_wave_failover():
    """Satellite: classifier-guided requests carry real (unique,
    monotone) rids into the merged queue, and a wave holding clf rows
    fails over a lost host bit-identically to the fault-free drain."""
    key = jax.random.PRNGKey(13)

    def submit_all(e):
        return [e.submit_classifier_guided(_lp_sq, 0, 3, num_steps=3,
                                           group="a"),
                e.submit(_enc(70), 1, 2, guidance=3.0, num_steps=2),
                e.submit_classifier_guided(_lp_shift, 2, 3, num_steps=2,
                                           group="b")]

    ref = _engine(hosts=2)
    rids = submit_all(ref)
    assert rids == sorted(set(rids)) and all(r >= 0 for r in rids)
    want = ref.run(key)

    eng = _engine(hosts=2,
                  faults=FaultInjector(schedule=[("window", 0, 0)]))
    rids2 = submit_all(eng)
    out = eng.run(key)
    assert eng.topology.failed == frozenset({0})
    for a, b in zip(rids, rids2):
        assert np.array_equal(want[a], out[b])
    s = eng.stats
    assert sum(p["rows"] for p in s["per_host"]) == s["generated"] == 8


def test_mixed_warm_store_replays_with_zero_sampler_calls(tmp_path):
    """Cross-mode warm-store replay: cfg AND uncond results persist
    under their (hash/synthetic, guidance, steps) keys, so a cold
    engine — any merged geometry — serves the repeat workload with zero
    waves."""
    key = jax.random.PRNGKey(11)
    warm = _engine(cache=True, store=SynthesisStore(tmp_path))
    rc = warm.submit(_enc(50), 0, 3, guidance=7.5, num_steps=3)
    ru = warm.submit_unconditional(3, category=1, num_steps=2)
    warm.submit_classifier_guided(_lp_sq, 1, 2, num_steps=2)  # uncached
    out = warm.run(key)
    for kw in (dict(), dict(hosts=2, compaction="full")):
        cold = _engine(cache=True, store=SynthesisStore(tmp_path), **kw)
        c1 = cold.submit(_enc(50), 0, 3, guidance=7.5, num_steps=3)
        c2 = cold.submit_unconditional(3, category=1, num_steps=2)
        got = cold.run(jax.random.PRNGKey(99))
        assert cold.stats["waves"] == 0 and cold.stats["generated"] == 0
        assert np.array_equal(got[c1], out[rc])
        assert np.array_equal(got[c2], out[ru])


def test_poisoned_classifier_fails_at_admission_on_merged_path():
    """A poisoned classifier closure is vetted BEFORE it can poison a
    mixed wave: with an on_error hook the bad request resolves to a
    typed failure at admission and every co-submitted request is still
    served; without the hook the legacy first-failure-raises contract
    holds and the queue stays intact."""
    def poisoned(x, labels):
        raise ValueError("poisoned classifier closure")

    eng = _engine()
    good = eng.submit(_enc(80), 0, 2, guidance=3.0, num_steps=2)
    bad = eng.submit_classifier_guided(poisoned, 1, 2, num_steps=2)
    also = eng.submit_unconditional(2, num_steps=2)
    errs = {}
    out = eng.run(jax.random.PRNGKey(3),
                  on_error=lambda rid, e: errs.__setitem__(rid, e))
    assert good in out and also in out and bad not in out
    assert isinstance(errs[bad], RequestFailedError)

    eng2 = _engine()
    eng2.submit(_enc(80), 0, 2, guidance=3.0, num_steps=2)
    eng2.submit_classifier_guided(poisoned, 1, 2, num_steps=2)
    with pytest.raises(ValueError, match="poisoned"):
        eng2.run(jax.random.PRNGKey(3))
    assert len(eng2._queue) == 2                 # nothing lost


def test_grouped_mode_keeps_legacy_paths_for_mixed_sets():
    """ragged=False engines keep the legacy per-mode wave groups (wave-
    keyed noise — NOT cross-oracle bit-comparable) but still serve a
    mixed submission set completely and replay deterministically."""
    key = jax.random.PRNGKey(21)

    def drain(e):
        rc = e.submit(_enc(90), 0, 3, guidance=7.5, num_steps=3)
        rl = e.submit_classifier_guided(_lp_sq, 1, 3, num_steps=3,
                                        group="g")
        ru = e.submit_unconditional(3, num_steps=3)
        out = e.run(key)
        return [out[r] for r in (rc, rl, ru)], e

    a, ea = drain(_engine(ragged=False))
    b, _ = drain(_engine(ragged=False))
    assert all(x.shape == (3, H, H, 3) for x in a)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    assert ea.stats["waves"] == 3                # one wave per legacy group
    assert {s[0] for s in ea.traj_shapes} == {"cfg", "clf", "uncond"}


def test_streamed_mixed_arrivals_extend_open_waves():
    """Mid-drain clf/uncond arrivals stream into the merged queue (one
    merged super-group) and come back bit-identical to their isolated
    oracles — admission order never keys noise."""
    key = jax.random.PRNGKey(17)
    eng = _engine()
    r0 = eng.submit(_enc(95), 0, 2, guidance=3.0, num_steps=2)
    late = {}
    calls = {"n": 0}

    def poll():
        calls["n"] += 1
        if calls["n"] == 1:
            late["clf"] = eng.submit_classifier_guided(
                _lp_shift, 1, 2, num_steps=2, group="late")
            late["unc"] = eng.submit_unconditional(2, num_steps=2)
            return True
        return False

    out = eng.run(key, poll=poll)
    assert eng.stats["streamed"] == 2
    for name, sub in [
            ("clf", lambda e: e.submit_classifier_guided(
                _lp_shift, 1, 2, num_steps=2, group="late")),
            ("unc", lambda e: e.submit_unconditional(2, num_steps=2))]:
        solo = _engine()
        solo._next_rid = late[name]
        srid = sub(solo)
        assert np.array_equal(out[late[name]], solo.run(key)[srid])
    assert out[r0].shape == (2, H, H, 3)
