"""End-to-end system tests: the OSCAR pipeline on a tiny config (single
communication round, server synthesis, global model), plus optimizer /
checkpoint substrate behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.oscar import DataConfig, DiffusionConfig, OscarConfig


@pytest.fixture(scope="module")
def tiny_exp(tmp_path_factory):
    from repro.core.experiment import Experiment
    ocfg = OscarConfig(
        data=DataConfig(num_categories=3, num_domains=3, train_per_cat_dom=6,
                        test_per_cat_dom=4),
        diffusion=DiffusionConfig(d_model=64, num_layers=2, num_heads=2,
                                  pretrain_steps=150, batch_size=32,
                                  sample_timesteps=10),
        classifier_steps=80, samples_per_category=6)
    return Experiment(ocfg, verbose=False,
                      cache_dir=str(tmp_path_factory.mktemp("dm")))


def test_oscar_single_round_above_chance(tiny_exp):
    res = tiny_exp.run("oscar")
    assert res["avg"] > 1.0 / 3 * 0.9          # above chance
    assert res["upload_params"] == 3 * 512     # C × 512, ONE round


def test_oscar_uploads_less_than_dm_baselines(tiny_exp):
    o = tiny_exp.run("oscar")
    d = tiny_exp.run("feddisc")
    assert o["upload_params"] < d["upload_params"]


def test_fl_baseline_runs(tiny_exp):
    res = tiny_exp.run("fedavg", rounds=2, local_steps=5)
    assert 0.0 <= res["avg"] <= 1.0
    assert res["upload_params"] > 0


def test_synthesis_labels_cover_all_categories(tiny_exp):
    from repro.core.oscar import client_encodings, synthesize
    enc, present = client_encodings(tiny_exp.fm, tiny_exp.data)
    sx, sy = synthesize(jax.random.PRNGKey(0), tiny_exp.dm_params,
                        tiny_exp.ocfg.diffusion, tiny_exp.sched, enc, present,
                        2, image_size=tiny_exp.ocfg.data.image_size)
    assert set(np.unique(sy)) == set(range(3))
    assert sx.shape[1:] == (16, 16, 3)
    assert np.abs(sx).max() <= 1.0
    # D_syn size = k · |R| · C (paper §IV-b)
    assert len(sx) == 2 * 3 * 3


def test_dm_cache_roundtrip(tiny_exp, tmp_path):
    from repro.checkpoint import io as ckpt
    p = tmp_path / "dm_test"
    ckpt.save_pytree(tiny_exp.dm_params, p, meta={"test": 1})
    loaded = ckpt.load_pytree(tiny_exp.dm_params, p)
    for a, b in zip(jax.tree.leaves(tiny_exp.dm_params),
                    jax.tree.leaves(loaded)):
        assert jnp.allclose(a, b)


def test_adamw_descends_quadratic():
    from repro.optim import adamw, apply_updates, init_adamw
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_adamw(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, opt = adamw(grads, opt, params, lr=5e-2)
        params = apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_cosine_schedule_warmup_and_decay():
    from repro.optim import cosine_schedule
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(1))) < 2e-4
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) <= 1e-3 * 0.11
