"""Serving-engine behaviour: wave batching equals sequential decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models.transformer import init_lm
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def setup(rng_key=jax.random.PRNGKey(0)):
    cfg = smoke_config(get_config("qwen2-7b"))
    params = init_lm(rng_key, cfg)
    return cfg, params


def test_engine_batches_equal_length_wave(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(3)]
    rids = [eng.submit(p, max_new=6) for p in prompts]
    res = eng.run()
    assert set(res) == set(rids)
    assert all(len(res[r]) == 6 for r in rids)
    assert eng.stats["waves"] == 1          # same length -> one wave
    assert eng.stats["prefilled"] == 3


def test_engine_matches_single_request_decode(setup):
    """Batched wave generation must equal running each request alone."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(2)]

    eng1 = ServeEngine(cfg, params, max_len=64)
    solo = {}
    for p in prompts:
        rid = eng1.submit(p, max_new=5)
        solo.update(eng1.run())

    eng2 = ServeEngine(cfg, params, max_len=64)
    rids = [eng2.submit(p, max_new=5) for p in prompts]
    batched = eng2.run()
    assert [batched[r] for r in rids] == list(solo.values())


def test_engine_mixed_lengths_split_into_waves(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    eng = ServeEngine(cfg, params, max_len=64)
    eng.submit(rng.integers(0, cfg.vocab_size, size=8), max_new=4)
    eng.submit(rng.integers(0, cfg.vocab_size, size=12), max_new=4)
    eng.submit(rng.integers(0, cfg.vocab_size, size=8), max_new=4)
    res = eng.run()
    assert len(res) == 3
    assert eng.stats["waves"] == 2


def test_engine_rejects_encoder():
    cfg = smoke_config(get_config("hubert-xlarge"))
    with pytest.raises(AssertionError):
        ServeEngine(cfg, params=None)


def test_eos_stops_early(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    # run once to discover what gets generated, then use token[1] as EOS
    rid = eng.submit(prompt, max_new=6)
    first = eng.run()[rid]
    eng2 = ServeEngine(cfg, params, max_len=64)
    rid2 = eng2.submit(prompt, max_new=6, eos=first[1])
    out = eng2.run()[rid2]
    assert len(out) <= 2 or out[1] != first[1]
