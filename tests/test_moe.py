"""MoE invariants: routing, load-balance aux, EP shard_map path vs the
dense oracle on a 1×1 mesh."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_config
from repro.models import moe as moe_mod
from repro.models.moe import Parallel


@pytest.fixture
def cfg():
    c = smoke_config(get_config("olmoe-1b-7b"))
    # generous capacity so EP vs dense comparison has no drops
    import dataclasses
    return c.replace(moe=dataclasses.replace(c.moe, capacity_factor=8.0))


def test_dense_path_shapes_and_aux(rng_key, cfg):
    p = moe_mod.init_moe(rng_key, cfg)
    x = jax.random.normal(rng_key, (2, 8, cfg.d_model))
    out, aux = moe_mod.moe_dense(p, cfg, x)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3   # E·Σf·P ≥ 1 (= 1 at perfect balance)


def test_ep_matches_dense_on_unit_mesh(rng_key, cfg):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    par = Parallel(model_axis="model", data_axes=("data",), mesh=mesh)
    p = moe_mod.init_moe(rng_key, cfg)
    x = jax.random.normal(rng_key, (2, 8, cfg.d_model))
    dense_out, dense_aux = moe_mod.moe_dense(p, cfg, x)
    ep_out, ep_aux = jax.jit(
        lambda p, x: moe_mod.moe_ep(p, cfg, x, par))(p, x)
    assert jnp.max(jnp.abs(dense_out - ep_out)) < 5e-4
    assert abs(float(dense_aux) - float(ep_aux)) < 1e-4


def test_router_gates_renormalised(rng_key, cfg):
    p = moe_mod.init_moe(rng_key, cfg)
    x = jax.random.normal(rng_key, (4, cfg.d_model))
    gates, idx, aux = moe_mod._route(p["w_router"], x, cfg.moe)
    assert jnp.allclose(jnp.sum(gates, -1), 1.0, atol=1e-5)
    assert gates.shape == (4, cfg.moe.top_k)
    assert int(jnp.max(idx)) < cfg.moe.num_experts


def test_capacity_drops_tokens(rng_key):
    """With capacity_factor → tiny, most tokens must be dropped (output
    becomes partial) but nothing crashes or NaNs."""
    import dataclasses
    c = smoke_config(get_config("olmoe-1b-7b"))
    c = c.replace(moe=dataclasses.replace(c.moe, capacity_factor=0.01))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    par = Parallel(model_axis="model", data_axes=("data",), mesh=mesh)
    p = moe_mod.init_moe(rng_key, c)
    x = jax.random.normal(rng_key, (2, 16, c.d_model))
    out, aux = moe_mod.moe_ep(p, c, x, par)
    assert bool(jnp.all(jnp.isfinite(out)))
    dense_out, _ = moe_mod.moe_dense(p, c, x)
    assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(dense_out))


def test_moe_grads_flow(rng_key, cfg):
    p = moe_mod.init_moe(rng_key, cfg)
    x = jax.random.normal(rng_key, (2, 8, cfg.d_model))

    def loss(p):
        out, aux = moe_mod.moe_dense(p, cfg, x)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    gsum = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert gsum > 0 and jnp.isfinite(gsum)
