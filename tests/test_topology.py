"""Topology-aware serving: HostTopology/WavePlacement and the placed
multi-host drain.

The load-bearing contract is PLACEMENT INVARIANCE: row noise is keyed by
request identity, so D_syn is bit-identical regardless of host count,
placement, packing mode (grouped/ragged/compacted), or arrival order —
a topology only decides WHERE a row is computed, never what it is.  The
oracle throughout is the plain single-host ragged engine.  The second
acceptance property is that any H>1 topology drives the segment-offset
``cfg_fuse`` row-window path (``row_offset = window.offset``) against
one wave-resident scalar table.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.schedule import make_schedule
from repro.serve import (HostTopology, HostWindow, SynthesisEngine,
                         SynthesisService, SynthesisStore, WavePlacement)

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8

_DM = None


def _dm():
    global _DM
    if _DM is None:
        key = jax.random.PRNGKey(0)
        params = init_dit(key, DC, H, 3)
        leaves, treedef = jax.tree.flatten(params)
        keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
        params = jax.tree.unflatten(treedef, [
            a + 0.05 * jax.random.normal(k, a.shape, a.dtype)
            for a, k in zip(leaves, keys)])
        _DM = params, make_schedule(DC.train_timesteps, DC.schedule)
    return _DM


def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


def _engine(**kw):
    params, sched = _dm()
    kw.setdefault("image_size", H)
    kw.setdefault("wave_size", 8)
    return SynthesisEngine(params, DC, sched, **kw)


def _mixed_requests(seed):
    """A random mixed (guidance, steps) classifier-free request set."""
    rng = np.random.default_rng(seed)
    subs = []
    for i in range(int(rng.integers(1, 5))):
        subs.append((_enc(100 * seed + i), int(rng.integers(0, 3)),
                     int(rng.integers(1, 6)),
                     float(rng.choice([1.5, 4.0, 7.5])),
                     int(rng.integers(1, 4))))
    return subs


def _run(subs, key, **kw):
    eng = _engine(**kw)
    rids = [eng.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in subs]
    out = eng.run(key)
    return [out[r] for r in rids], eng


# ---------------------------------------------------------------------------
# HostTopology / WavePlacement units
# ---------------------------------------------------------------------------

def test_simulated_topology_shape():
    t = HostTopology.simulated(3, granule=4)
    assert t.num_hosts == 3
    assert t.device_counts == (1, 1, 1) and t.granules == (4, 4, 4)
    assert [t.assign(r) for r in range(5)] == [0, 1, 2, 0, 1]
    assert t.wave_quotas(24) == (8, 8, 8)
    # shares never drop below one granule
    assert t.wave_quotas(2) == (4, 4, 4)


@pytest.mark.parametrize("bad", [0, -1, True, "2"])
def test_simulated_topology_rejects_bad_host_count(bad):
    with pytest.raises(ValueError, match="hosts"):
        HostTopology.simulated(bad)


def test_topology_validates_fields():
    with pytest.raises(ValueError, match="at least one host"):
        HostTopology(device_counts=(), granules=())
    with pytest.raises(ValueError, match="granules"):
        HostTopology(device_counts=(1, 1), granules=(1,))
    with pytest.raises(ValueError, match=">= 1"):
        HostTopology(device_counts=(1, 0), granules=(1, 1))


def test_topology_from_mesh_partitions_data_axis():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(jax.device_count(), 1)
    t = HostTopology.from_mesh(mesh, 1)
    assert t.num_hosts == 1 and t.mesh is mesh
    # more hosts than data-parallel devices: actionable refusal
    with pytest.raises(ValueError, match="hosts must divide"):
        HostTopology.from_mesh(mesh, 2)
    with pytest.raises(ValueError, match="hosts"):
        HostTopology.from_mesh(mesh)           # host count required


def test_wave_placement_windows_tile_the_wave():
    p = WavePlacement.plan([3, 0, 5], granules=[4, 4, 4])
    assert [w.host for w in p.windows] == [0, 2]   # empty host: no window
    assert [(w.offset, w.rows, w.real) for w in p.windows] == \
        [(0, 4, 3), (4, 8, 5)]
    assert p.total_rows == 12 and p.real_rows == 8 and p.padded == 4
    with pytest.raises(ValueError, match="granules"):
        WavePlacement.plan([1, 2], granules=[1])


def test_wave_placement_rejects_gapped_windows():
    with pytest.raises(ValueError, match="contiguously"):
        WavePlacement(windows=(HostWindow(0, 0, 4, 4),
                               HostWindow(1, 8, 4, 4)))
    with pytest.raises(ValueError, match="real"):
        HostWindow(0, 0, 4, 5)


# ---------------------------------------------------------------------------
# placement invariance: the acceptance property
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 6), hosts=st.sampled_from([1, 2, 4]),
       mode=st.sampled_from(["grouped", "ragged", "compacted"]))
@settings(max_examples=12, deadline=None)
def test_placed_drain_bit_identical_to_single_host_fuzzed(seed, hosts, mode):
    """Property: ANY WavePlacement of a random mixed (guidance, steps)
    request set over H ∈ {1, 2, 4} simulated hosts — grouped, ragged, or
    compacted — is bit-identical to the plain single-host ragged engine
    on the same requests and drain key."""
    subs = _mixed_requests(seed)
    key = jax.random.PRNGKey(1000 + seed)
    oracle, _ = _run(subs, key, ragged=True)
    kw = {"grouped": dict(ragged=False), "ragged": dict(ragged=True),
          "compacted": dict(compaction="full")}[mode]
    outs, eng = _run(subs, key, hosts=hosts, **kw)
    assert eng.topology is not None and eng.topology.num_hosts == hosts
    for a, b in zip(oracle, outs):
        assert np.array_equal(a, b)


@given(seed=st.integers(0, 4), hosts=st.sampled_from([2, 4]))
@settings(max_examples=8, deadline=None)
def test_placed_streaming_matches_upfront_trace_fuzzed(seed, hosts):
    """Property: requests streamed into a placed drain mid-flight land on
    the same hosts (identity routing) and produce the same bits as the
    whole trace submitted up front."""
    subs = _mixed_requests(seed) + _mixed_requests(seed + 50)
    key = jax.random.PRNGKey(2000 + seed)
    upfront, _ = _run(subs, key, hosts=hosts, ragged=True)

    params, sched = _dm()
    svc = SynthesisService(_engine(ragged=True, hosts=hosts))
    half = max(len(subs) // 2, 1)
    futs = [svc.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in subs[:half]]
    trace = list(subs[half:])

    def poll():
        if not trace:
            return False
        e, c, n, g, s = trace.pop(0)
        futs.append(svc.submit(e, c, n, guidance=g, num_steps=s))
        return True

    svc.drain(key, poll=poll)
    for a, f in zip(upfront, futs):
        assert np.array_equal(a, f.result())


def test_warm_store_replay_crosses_topologies():
    """A store warmed by a single-host ragged drain serves every
    topology/mode with ZERO sampler calls, bit-identically — cache and
    store keys do not know the serving layout."""
    import tempfile
    subs = _mixed_requests(3)
    key = jax.random.PRNGKey(33)
    root = tempfile.mkdtemp(prefix="dsyn_topo_")
    warm = SynthesisService(_engine(ragged=True), store=SynthesisStore(root))
    futs = [warm.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in subs]
    outs = warm.gather(futs, key)
    for hosts, kw in [(2, dict(ragged=True)), (4, dict(compaction="full")),
                      (2, dict(ragged=False))]:
        cold = SynthesisService(_engine(hosts=hosts, **kw),
                                store=SynthesisStore(root))
        fc = [cold.submit(e, c, n, guidance=g, num_steps=s)
              for e, c, n, g, s in subs]
        got = cold.gather(fc, key)
        assert cold.stats["generated"] == 0, "warm store must skip sampling"
        for a, b in zip(outs, got):
            assert np.array_equal(a, b)


def test_multi_host_drives_row_window_kernel_path(monkeypatch):
    """Acceptance: under any H>1 topology the production cfg_fuse path is
    the segment-offset row-window variant — every window reads the
    wave-resident scalar table at ``row_offset = window.offset``, and at
    least one window sits at a non-zero offset.  The offset is a TRACED
    operand of the window executable (so hosts share compiles): the
    kernel-level spy sees a tracer, and the concrete offsets are read at
    the jit boundary instead."""
    import repro.serve.synthesis as synth_mod
    from repro.kernels.cfg_fuse import ref as cfg_ref
    windowed_hits = []
    real = cfg_ref.cfg_update_rowwise_windowed

    def spy(x, eps_c, eps_u, s, ab_t, ab_prev, noise, active,
            row_offset=0, eta=1.0):
        windowed_hits.append(row_offset)
        return real(x, eps_c, eps_u, s, ab_t, ab_prev, noise, active,
                    row_offset=row_offset, eta=eta)

    monkeypatch.setattr(cfg_ref, "cfg_update_rowwise_windowed", spy)
    offsets = []
    real_seg = synth_mod._window_segment

    def seg_spy(*a, **kw):
        offsets.append(int(kw["row_offset"]))
        return real_seg(*a, **kw)

    monkeypatch.setattr(synth_mod, "_window_segment", seg_spy)
    # geometry unique to this test (wave_size 12, granule 3): the jitted
    # window segments must TRACE here, not hit another test's executable
    subs = [(_enc(900), 0, 5, 7.5, 3), (_enc(901), 1, 4, 1.5, 2),
            (_enc(902), 2, 3, 4.0, 3)]
    outs, eng = _run(subs, jax.random.PRNGKey(77), hosts=2, ragged=True,
                     wave_size=12, granule=3)
    assert windowed_hits, "H=2 drain never hit the row-window cfg_fuse path"
    assert any(o > 0 for o in offsets), \
        f"all windows sampled at offset 0: {offsets}"
    oracle, _ = _run(subs, jax.random.PRNGKey(77), ragged=True,
                     wave_size=12, granule=3)
    for a, b in zip(oracle, outs):
        assert np.array_equal(a, b)


def test_compacted_windows_drive_row_window_kernel_path(monkeypatch):
    """Compaction composes with placement: each host's activation-sorted
    window epoch-plans locally, and its SEGMENTS still read the wave
    table through their window's non-zero row offset."""
    import repro.serve.synthesis as synth_mod
    offsets = []
    real_seg = synth_mod._window_segment

    def seg_spy(*a, **kw):
        offsets.append(int(kw["row_offset"]))
        return real_seg(*a, **kw)

    monkeypatch.setattr(synth_mod, "_window_segment", seg_spy)
    subs = [(_enc(910), 0, 5, 7.5, 3), (_enc(911), 1, 5, 1.5, 1),
            (_enc(912), 2, 4, 4.0, 2)]
    outs, eng = _run(subs, jax.random.PRNGKey(78), hosts=2,
                     compaction="full", wave_size=14, granule=7)
    assert any(o > 0 for o in offsets), \
        f"compacted windows never used a non-zero offset: {offsets}"
    assert eng.stats["segments"] > 0
    oracle, _ = _run(subs, jax.random.PRNGKey(78), ragged=True,
                     wave_size=14, granule=7)
    for a, b in zip(oracle, outs):
        assert np.array_equal(a, b)


def test_sample_cfg_window_matches_full_wave_slice():
    """Sampler-level contract: a window of a ragged wave — window-local
    conditioning/keys against the wave-wide (guidance, steps) scalar
    table — reproduces the same rows of the full-wave scan bit-exactly,
    at any offset."""
    from repro.diffusion.sampler import sample_cfg_ragged, sample_cfg_window
    params, sched = _dm()
    B = 6
    y = jax.random.normal(jax.random.PRNGKey(21), (B, DC.cond_dim))
    rk = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(22), i))(
        jnp.arange(B, dtype=jnp.uint32))
    g = jnp.array([7.5, 1.5, 4.0, 7.5, 1.5, 4.0], jnp.float32)
    steps = np.array([3, 2, 3, 1, 2, 3], np.int32)
    full = sample_cfg_ragged(params, DC, sched, y, rk, g, steps,
                             image_size=H)
    for off, rows in [(0, 2), (2, 3), (5, 1), (0, 6)]:
        win = sample_cfg_window(params, DC, sched, y[off:off + rows],
                                rk[off:off + rows], g, steps,
                                row_offset=off, image_size=H)
        assert np.array_equal(np.asarray(full[off:off + rows]),
                              np.asarray(win))
    with pytest.raises(ValueError, match="out of range"):
        sample_cfg_window(params, DC, sched, y[4:], rk[4:], g, steps,
                          row_offset=5, image_size=H)
    with pytest.raises(ValueError, match="rows"):
        sample_cfg_window(params, DC, sched, y[:2], rk[:3], g, steps,
                          row_offset=0, image_size=H)


# ---------------------------------------------------------------------------
# per-host observability
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [dict(ragged=True),
                                  dict(compaction="full"),
                                  dict(ragged=False)])
def test_per_host_stats_sum_to_global_counters(mode):
    subs = _mixed_requests(7)
    svc = SynthesisService(_engine(hosts=2, **mode))
    futs = [svc.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in subs]
    svc.gather(futs, jax.random.PRNGKey(9))
    s = svc.stats
    assert s["hosts"] == 2 and len(s["per_host"]) == 2
    per = s["per_host"]
    assert sum(p["rows"] + p["padded"] for p in per) == s["scheduled_rows"]
    assert sum(p["rows"] for p in per) == s["generated"]
    assert sum(p["padded"] for p in per) == s["padded"]
    assert sum(p["row_iters_scheduled"] for p in per) \
        == s["row_iters_scheduled"]
    assert sum(p["row_iters_active"] for p in per) == s["row_iters_active"]
    # identity routing fills the ingress queues before the first wave
    assert sum(p["queue_depth_at_start"] for p in per) \
        == sum(n for _, _, n, _, _ in subs)
    # useful work is the workload's own step sum, host split or not
    assert s["row_iters_active"] == sum(n * st_ for _, _, n, _, st_ in subs)


def test_full_compaction_schedules_exactly_active_per_host():
    subs = [(_enc(30), 0, 4, 7.5, 3), (_enc(31), 1, 4, 1.5, 2),
            (_enc(32), 2, 4, 4.0, 1), (_enc(33), 0, 4, 1.5, 3)]
    _, eng = _run(subs, jax.random.PRNGKey(41), hosts=2, compaction="full",
                  granule=1, wave_size=8)
    for p in eng.stats["per_host"]:
        assert p["row_iters_scheduled"] == p["row_iters_active"]
    assert eng.stats["padded"] == 0


# ---------------------------------------------------------------------------
# knob threading + opt-in contract
# ---------------------------------------------------------------------------

def test_topology_opt_in_contract():
    eng = _engine()
    assert eng.topology is None
    SynthesisService(eng, hosts=2)
    assert eng.topology is not None and eng.topology.num_hosts == 2
    # opt-in only: constructing without the knob leaves it alone
    SynthesisService(eng)
    assert eng.topology.num_hosts == 2
    with pytest.raises(ValueError, match="topology"):
        eng.set_topology(True)
    t = HostTopology.simulated(3)
    eng2 = _engine(topology=t)
    assert eng2.topology is t


def test_reapplied_topology_keeps_per_host_stats():
    """A shared engine's opt_in re-threads the same hosts= knob on every
    entry point; an EQUAL topology must be a no-op, not a counter wipe —
    the per-host sums stay equal to the global counters across runs."""
    eng = _engine(hosts=2, ragged=True)
    subs = _mixed_requests(11)
    for e, c, n, g, s in subs:
        eng.submit(e, c, n, guidance=g, num_steps=s)
    eng.run(jax.random.PRNGKey(1))
    rows_before = [p["rows"] for p in eng.stats["per_host"]]
    assert sum(rows_before) > 0
    eng.opt_in(ragged=True, hosts=2)        # a second entry point
    SynthesisService(eng, hosts=2)          # and a service wrap
    assert [p["rows"] for p in eng.stats["per_host"]] == rows_before
    assert sum(p["rows"] + p["padded"] for p in eng.stats["per_host"]) \
        == eng.stats["scheduled_rows"]


def test_mesh_backed_topology_places_windows_on_host_submesh():
    """A topology derived from a serving mesh routes every window's
    tensors through the row-window sharding rule (wave_window_specs on
    the host submesh) — and the placed outputs still match the plain
    ragged oracle bit for bit."""
    from repro.launch.mesh import make_serving_mesh
    mesh = make_serving_mesh(hosts=1, data=jax.device_count(), model=1)
    subs = _mixed_requests(13)
    key = jax.random.PRNGKey(55)
    oracle, _ = _run(subs, key, ragged=True)
    outs, eng = _run(subs, key, ragged=True, mesh=mesh, hosts=1)
    assert eng.topology.mesh is mesh
    sub = eng.topology.host_mesh(0)
    assert sub.axis_names == ("data", "model")
    sh = eng._window_shardings(0)
    assert sh is not None and sh["y"].mesh.axis_names == ("data", "model")
    for a, b in zip(oracle, outs):
        assert np.array_equal(a, b)
    # a plain (data, model) mesh partitions its leading data axis
    from repro.launch.mesh import make_host_mesh
    plain = HostTopology.from_mesh(make_host_mesh(jax.device_count(), 1), 1)
    assert plain.host_mesh(0).axis_names == ("data", "model")
    with pytest.raises(ValueError, match="out of range"):
        plain.host_mesh(1)
    # simulated topologies have no meshes to place on
    assert HostTopology.simulated(2).host_mesh(0) is None


def test_run_paths_thread_hosts_knob():
    from repro.core.oscar import synthesize
    params, sched = _dm()
    enc = np.stack([np.stack([_enc(60 + c) for c in range(3)])])
    present = np.ones((1, 3), bool)
    eng = _engine()
    sx, sy = synthesize(jax.random.PRNGKey(0), params, DC, sched, enc,
                        present, 2, image_size=H, engine=eng, ragged=True,
                        hosts=2)
    assert eng.topology is not None and eng.topology.num_hosts == 2
    assert sx.shape == (6, H, H, 3)
    assert eng.stats["per_host"][0]["rows"] + \
        eng.stats["per_host"][1]["rows"] == 6


def test_clf_and_uncond_rows_place_with_cfg_waves():
    """Under ragged scheduling EVERY mode places: clf/uncond rows ride
    the merged waves and shard over hosts like any cfg row — the whole
    mixed workload lands in the per-host breakdown, and the placed
    result is bit-identical to the single-host merged engine."""
    key = jax.random.PRNGKey(6)
    lp = lambda x, labels: -jnp.sum(x ** 2, axis=(1, 2, 3))

    def submit_all(e):
        return (e.submit(_enc(20), 0, 3, guidance=7.5, num_steps=3),
                e.submit_classifier_guided(lp, 1, 3, group="client0",
                                           num_steps=3),
                e.submit_unconditional(3))

    eng = _engine(hosts=2, ragged=True)
    rc, rl, ru = submit_all(eng)
    out = eng.run(key)
    assert out[rc].shape == out[rl].shape == out[ru].shape == (3, H, H, 3)
    # ALL nine rows land in the per-host breakdown now
    assert sum(p["rows"] for p in eng.stats["per_host"]) == 9
    assert eng.stats["generated"] == 9
    solo = _engine(ragged=True)
    sc, sl, su = submit_all(solo)
    sout = solo.run(key)
    for a, b in ((rc, sc), (rl, sl), (ru, su)):
        assert np.array_equal(out[a], sout[b])


def test_cache_topup_under_topology():
    """(encoding-hash, guidance, steps) caching is unchanged under a
    topology: resubmission hits, larger counts top up the cached prefix,
    2-D encodings stay single entries."""
    eng = _engine(hosts=2, ragged=True)
    enc = _enc(300)
    ra = eng.submit(enc, 0, 4, guidance=7.5)
    first = eng.run(jax.random.PRNGKey(3))[ra]
    waves = eng.stats["waves"]
    rb = eng.submit(enc, 0, 4, guidance=7.5)
    assert np.array_equal(eng.run(jax.random.PRNGKey(99))[rb], first)
    assert eng.stats["waves"] == waves             # pure cache hit
    rc = eng.submit(enc, 0, 7, guidance=7.5)
    more = eng.run(jax.random.PRNGKey(4))[rc]
    assert more.shape[0] == 7 and np.array_equal(more[:4], first)
    mat = np.stack([_enc(310 + i) for i in range(4)])
    rd = eng.submit(mat, 0, guidance=1.5, num_steps=2)
    out = eng.run(jax.random.PRNGKey(5))[rd]
    assert out.shape == (4, H, H, 3)


def test_per_host_store_handles_merge_into_one_root():
    """H hosts flushing concurrently into one store root is the
    tombstoned-manifest-merge contract: every host's handle keeps the
    entries the others flushed, and a cold reader serves them all."""
    import tempfile
    root = tempfile.mkdtemp(prefix="dsyn_hosts_")
    handles = [SynthesisStore(root) for _ in range(3)]    # one per host
    rows = {h: np.full((2, 4, 4, 3), float(h), np.float32)
            for h in range(3)}
    keys = {h: (f"enc{h}", 7.5, 3) for h in range(3)}
    for h, store in enumerate(handles):
        store.put(keys[h], rows[h])
    for store in handles:                                  # any flush order
        store.flush()
    cold = SynthesisStore(root)
    assert len(cold) == 3
    for h in range(3):
        assert np.array_equal(cold.get(keys[h]), rows[h])
