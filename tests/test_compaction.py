"""Compute-skipping ragged scheduling: iteration-compacted nested waves.

The contract under test is BIT-EXACTNESS UNDER ANY RE-PACKING: a row's
output depends only on its own (encoding, guidance, steps, noise key), so
running the ragged reverse process as compaction segments — any segment
boundaries, any epoch count, any wave interleaving, any arrival trace —
must reproduce the one-shot ragged scan (and the row's isolated uniform
wave) bit for bit.  Because that property is quantified over schedules,
the harness here is PROPERTY-BASED: fuzzed step tables, fuzzed epoch
boundaries, and fuzzed arrival traces are all driven through the
hypothesis shim against the one-shot oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.guidance import plan_epochs
from repro.diffusion.sampler import sample_cfg_compacted, sample_cfg_ragged
from repro.diffusion.schedule import make_schedule
from repro.serve import SynthesisEngine, SynthesisService, SynthesisStore

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8

_DM = None


def _dm():
    """Module-memoised tiny DM (plain function, not a pytest fixture, so
    @given tests can use it without tripping hypothesis' fixture health
    check when the real library is installed)."""
    global _DM
    if _DM is None:
        key = jax.random.PRNGKey(0)
        params = init_dit(key, DC, H, 3)
        leaves, treedef = jax.tree.flatten(params)
        keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
        params = jax.tree.unflatten(treedef, [
            a + 0.05 * jax.random.normal(k, a.shape, a.dtype)
            for a, k in zip(leaves, keys)])
        _DM = params, make_schedule(DC.train_timesteps, DC.schedule)
    return _DM


def _row_keys(base, n):
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(n, dtype=jnp.uint32))


def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


# ---------------------------------------------------------------------------
# plan_epochs: the epoch partition itself
# ---------------------------------------------------------------------------

def test_plan_epochs_full_covers_every_start():
    steps = np.array([4, 2, 4, 1, 3], np.int32)
    order, epochs = plan_epochs(steps, 4, compaction="full")
    # sorted by activation: deepest rows first, stably
    assert np.array_equal(steps[order], [4, 4, 3, 2, 1])
    # one epoch per distinct start, contiguous, ending at max_steps
    assert epochs == ((2, 0, 1), (3, 1, 2), (4, 2, 3), (5, 3, 4))
    # full compaction schedules exactly the true sum of per-row steps
    assert sum(n * (e - b) for n, b, e in epochs) == int(steps.sum())


def test_plan_epochs_skips_dead_head_iterations():
    """A step ceiling above the deepest row (the engine's running smax)
    leaves leading iterations with NO live rows — the first epoch starts
    at the earliest activation, not 0."""
    _, epochs = plan_epochs(np.array([3, 2], np.int32), 8, compaction="full")
    assert epochs[0][1] == 5                       # 8 - max(steps)
    assert sum(n * (e - b) for n, b, e in epochs) == 5


def test_plan_epochs_k_cap_merges_cheapest_boundary():
    steps = np.array([8, 8, 8, 8, 7, 1], np.int32)
    _, epochs = plan_epochs(steps, 8, compaction=2)
    # merging the 1-row epoch at start=1 freezes 1 row-iter; merging the
    # start=7 boundary would freeze 7 — the cap keeps the expensive one
    assert len(epochs) == 2
    assert epochs == ((5, 0, 7), (6, 7, 8))
    _, one = plan_epochs(steps, 8, compaction=1)
    assert one == ((6, 0, 8),)


def test_plan_epochs_auto_cost_model_and_shape_buckets():
    steps = np.array([6, 6, 6, 6, 2, 2], np.int32)
    # splitting at start=4 saves 2 rows x 4 iters = 8 frozen row-iters
    _, cheap = plan_epochs(steps, 6, compaction="auto", compile_cost=8)
    assert len(cheap) == 2
    _, dear = plan_epochs(steps, 6, compaction="auto", compile_cost=9)
    assert len(dear) == 1
    # ...unless the segment geometry is already compiled: a shape-bucket
    # hit — keyed (carried, rows, length), the jitted executable's own
    # specialization key — makes the split free
    _, bucketed = plan_epochs(steps, 6, compaction="auto", compile_cost=9,
                              geoms={(0, 4, 4)})
    assert len(bucketed) == 2
    # a bucket recorded under a different carried-row count is NOT the
    # same executable, so it cannot make this split free
    _, missed = plan_epochs(steps, 6, compaction="auto", compile_cost=9,
                            geoms={(2, 4, 4)})
    assert len(missed) == 1


def test_plan_epochs_granule_rounds_rows_up():
    steps = np.array([4, 4, 4, 2, 2], np.int32)
    _, epochs = plan_epochs(steps, 4, compaction="full", granule=4)
    # 3 live rows round up to 4: the 4th row is a future arrival admitted
    # early (frozen by the active mask — values unchanged)
    assert epochs == ((4, 0, 2), (5, 2, 4))


def test_plan_epochs_rejects_bad_inputs():
    with pytest.raises(ValueError, match="empty"):
        plan_epochs(np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError, match=">= 1"):
        plan_epochs(np.array([2, 0]), 4)
    with pytest.raises(ValueError, match="max_steps"):
        plan_epochs(np.array([5]), 4)
    with pytest.raises(ValueError, match="compaction"):
        plan_epochs(np.array([2, 1]), 4, compaction="fastest")
    with pytest.raises(ValueError, match="compaction"):
        # bool is an int subclass: True must not be read as K=1
        plan_epochs(np.array([2, 1]), 4, compaction=True)


@given(seed=st.integers(0, 10), smax_extra=st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_plan_epochs_invariants_fuzzed(seed, smax_extra):
    """Any plan — full, capped, auto — is a valid nested-wave schedule:
    epochs tile [first start, max_steps), row counts are non-decreasing
    prefixes ending at B, and every row's active iterations are covered
    by epochs that include it."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 9))
    smax0 = int(rng.integers(1, 9))
    steps = rng.integers(1, smax0 + 1, B).astype(np.int32)
    S = int(steps.max()) + smax_extra
    for compaction in ("full", "auto", int(rng.integers(1, 5))):
        order, epochs = plan_epochs(steps, S, compaction=compaction,
                                    compile_cost=int(rng.integers(0, 20)))
        ss = (S - steps)[order]
        assert np.all(np.diff(ss) >= 0)            # activation-sorted
        assert epochs[0][1] == int(ss[0])          # dead head skipped
        assert epochs[-1][2] == S
        assert epochs[-1][0] == B
        prev_rows, prev_end = 0, epochs[0][1]
        for rows, begin, end in epochs:
            assert begin == prev_end and end > begin
            assert rows >= prev_rows
            # every row live in this epoch is present in its batch
            assert rows >= np.searchsorted(ss, end, side="left")
            prev_rows, prev_end = rows, end


# ---------------------------------------------------------------------------
# sampler core: compacted vs one-shot ragged vs isolated uniform waves
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("compaction", ["full", "auto", 2])
def test_compacted_bit_exact_vs_ragged_and_isolated(compaction, use_pallas):
    """The acceptance parity: every compaction of a mixed wave reproduces
    the one-shot ragged scan bit for bit, and each (guidance, steps)
    group inside it matches the same rows sampled alone as a uniform
    wave — nested segments are invisible to row values."""
    params, sched = _dm()
    B = 6
    y = jax.random.normal(jax.random.PRNGKey(1), (B, DC.cond_dim))
    rk = _row_keys(jax.random.PRNGKey(7), B)
    g = jnp.array([7.5, 7.5, 1.5, 1.5, 4.0, 4.0], jnp.float32)
    steps = np.array([3, 3, 2, 2, 3, 1], np.int32)
    ragged = sample_cfg_ragged(params, DC, sched, y, rk, g, steps,
                               image_size=H, use_pallas=use_pallas)
    comp = sample_cfg_compacted(params, DC, sched, y, rk, g, steps,
                                image_size=H, compaction=compaction,
                                use_pallas=use_pallas)
    assert np.array_equal(np.asarray(ragged), np.asarray(comp))
    for idx in ([0, 1], [2, 3], [4], [5]):
        i = np.array(idx)
        iso = sample_cfg_ragged(params, DC, sched, y[i], rk[i], g[i],
                                steps[i], image_size=H,
                                use_pallas=use_pallas)
        assert np.array_equal(np.asarray(comp[i]), np.asarray(iso))


def test_compacted_rejects_malformed_caller_plan():
    """A caller-supplied ``plan`` that stops early, leaves a gap, or
    shrinks its row counts must be refused — a truncated scan would
    silently return half-denoised rows."""
    params, sched = _dm()
    B = 3
    y = jax.random.normal(jax.random.PRNGKey(3), (B, DC.cond_dim))
    rk = _row_keys(jax.random.PRNGKey(9), B)
    g = jnp.full((B,), 7.5)
    steps = np.array([3, 3, 2], np.int32)
    order = np.arange(B)
    bad_plans = [
        (order, ()),                           # empty
        (order, ((B, 0, 2),)),                 # stops before S=3
        (order, ((2, 0, 1), (B, 2, 3))),       # gap between segments
        (order, ((B, 0, 1), (2, 1, 3))),       # row count shrinks
        (order, ((B, 0, 0), (B, 0, 3))),       # empty segment
        (order, ((B, -1, 3),)),                # negative begin
        (order, ((B, 2, 3),)),                 # skips active iterations:
                                               # 3-step rows start at 0
        (order, ((1, 0, 2), (B, 2, 3))),       # first epoch excludes a
                                               # row already active there
    ]
    for plan in bad_plans:
        with pytest.raises(ValueError,
                           match="epoch|rows|iteration"):
            sample_cfg_compacted(params, DC, sched, y, rk, g, steps,
                                 plan=plan, image_size=H)
    # the well-formed plan (what plan_epochs emits) still samples
    good = plan_epochs(steps, 3, compaction="full")
    out = sample_cfg_compacted(params, DC, sched, y, rk, g, steps,
                               plan=good, image_size=H)
    assert out.shape == (B, H, H, 3)


def test_compacted_independent_of_step_ceiling():
    """A higher step ceiling only lengthens the skipped dead head —
    outputs are bit-identical, so the engine's running smax never
    invalidates a row."""
    params, sched = _dm()
    y = jax.random.normal(jax.random.PRNGKey(2), (3, DC.cond_dim))
    rk = _row_keys(jax.random.PRNGKey(8), 3)
    g = jnp.full((3,), 7.5)
    steps = np.array([3, 2, 1], np.int32)
    a = sample_cfg_compacted(params, DC, sched, y, rk, g, steps,
                             image_size=H)
    b = sample_cfg_compacted(params, DC, sched, y, rk, g, steps,
                             max_steps=6, image_size=H)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@given(seed=st.integers(0, 8), compaction=st.sampled_from(["full", "auto",
                                                           2, 3]))
@settings(max_examples=6, deadline=None)
def test_fuzzed_schedules_and_boundaries_bit_exact(seed, compaction):
    """The fuzzed parity harness: random per-row (guidance, steps)
    tables, random step ceilings, random epoch boundaries (via the
    compaction modes AND a randomly merged custom plan) — all must
    reproduce the one-shot ragged oracle bit for bit."""
    params, sched = _dm()
    rng = np.random.default_rng(seed)
    B = int(rng.integers(2, 7))
    steps = rng.integers(1, 4, B).astype(np.int32)
    S = int(steps.max()) + int(rng.integers(0, 3))
    g = jnp.asarray(rng.choice([1.5, 4.0, 7.5], B).astype(np.float32))
    y = jax.random.normal(jax.random.PRNGKey(seed), (B, DC.cond_dim))
    rk = _row_keys(jax.random.PRNGKey(100 + seed), B)
    oracle = np.asarray(sample_cfg_ragged(params, DC, sched, y, rk, g,
                                          steps, max_steps=S, image_size=H))
    comp = sample_cfg_compacted(params, DC, sched, y, rk, g, steps,
                                max_steps=S, image_size=H,
                                compaction=compaction,
                                compile_cost=int(rng.integers(0, 16)))
    assert np.array_equal(oracle, np.asarray(comp))
    # a custom plan with a random subset of the full boundaries merged —
    # compaction boundaries anywhere must not leak into row values
    order, full = plan_epochs(steps, S, compaction="full")
    keep = [e for i, e in enumerate(full)
            if i == 0 or rng.random() < 0.5]
    epochs = tuple((keep[i + 1][0] if i + 1 < len(keep) else full[-1][0],
                    b, keep[i + 1][1] if i + 1 < len(keep) else S)
                   for i, (_, b, _) in enumerate(keep))
    custom = sample_cfg_compacted(params, DC, sched, y, rk, g, steps,
                                  max_steps=S, image_size=H,
                                  plan=(order, epochs))
    assert np.array_equal(oracle, np.asarray(custom))


# ---------------------------------------------------------------------------
# engine + service: packing invariance under fuzzed traces
# ---------------------------------------------------------------------------

def _engine(**kw):
    params, sched = _dm()
    kw.setdefault("image_size", H)
    kw.setdefault("wave_size", 8)
    return SynthesisEngine(params, DC, sched, **kw)


_REQS = [(_enc(40), 0, 3, 1.5, 3), (_enc(41), 1, 2, 7.5, 2),
         (_enc(42), 2, 4, 7.5, 3), (_enc(43), 0, 2, 4.0, 1),
         (_enc(44), 1, 3, 1.5, 2)]


def _run_trace(eng, key, split, wave_order_seed=None):
    """Submit _REQS with the first ``split`` up front and the rest
    streamed in one-per-poll mid-drain; returns rows per request in
    submission order (the submission SEQUENCE is fixed — request identity
    keys the noise — while the arrival trace varies)."""
    svc = SynthesisService(eng, key=0)
    futs = [svc.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in _REQS[:split]]
    trace = list(_REQS[split:])

    def poll():
        if not trace:
            return False
        e, c, n, g, s = trace.pop(0)
        futs.append(svc.submit(e, c, n, guidance=g, num_steps=s))
        return True

    svc.drain(key, poll=poll)
    return [f.result() for f in futs]


@given(seed=st.integers(0, 6))
@settings(max_examples=4, deadline=None)
def test_fuzzed_packing_invariance_across_modes_and_traces(seed):
    """Acceptance: random arrival traces (upfront/streamed split, wave
    sizes) × scheduling modes (one-shot ragged; full/auto/capped
    compaction) all produce BIT-IDENTICAL D_syn for every request — the
    schedule, the packing, and mid-drain admissions are invisible."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(3)
    baseline = _run_trace(_engine(ragged=True), key, split=len(_REQS))
    for _ in range(2):
        split = int(rng.integers(1, len(_REQS) + 1))
        wave = int(rng.choice([4, 8, 16]))
        compaction = rng.choice(["off", "full", "auto", "2"])
        compaction = int(compaction) if compaction == "2" else compaction
        eng = _engine(ragged=True, wave_size=wave, compaction=compaction,
                      compaction_compile_cost=int(rng.integers(0, 12)))
        outs = _run_trace(eng, key, split=split)
        for a, b in zip(baseline, outs):
            assert np.array_equal(a, b)


def test_compacted_store_and_cache_keys_match_all_modes(tmp_path):
    """grouped, ragged, and compacted engines must agree on cache keys
    and persistent store identity — same manifest slugs, same entry keys
    — so any of them can serve a store the others warmed.  (Row VALUES
    are only comparable between ragged and compacted, whose noise is
    request-keyed; grouped waves draw batch noise.)"""
    import json
    slugs, cache_keys = [], []
    for mode, kw in [("grouped", dict()), ("ragged", dict(ragged=True)),
                     ("compacted", dict(compaction="full"))]:
        store = SynthesisStore(tmp_path / mode)
        eng = _engine(store=store, **kw)
        for e, c, n, g, s in _REQS:
            eng.submit(e, c, n, guidance=g, num_steps=s)
        eng.run(jax.random.PRNGKey(4))
        man = json.loads((tmp_path / mode / "manifest.json").read_text())
        slugs.append(sorted(man["entries"].keys()))
        cache_keys.append(sorted(eng._cache.keys()))
    assert slugs[0] == slugs[1] == slugs[2]
    assert cache_keys[0] == cache_keys[1] == cache_keys[2]
    # and a compacted engine serves a ragged-warmed store with zero
    # sampler calls, bit-identically
    params, sched = _dm()
    warm = _engine(ragged=True, store=SynthesisStore(tmp_path / "shared"))
    rids = [warm.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in _REQS]
    out_warm = warm.run(jax.random.PRNGKey(5))
    cold = _engine(compaction="full",
                   store=SynthesisStore(tmp_path / "shared"))
    rids2 = [cold.submit(e, c, n, guidance=g, num_steps=s)
             for e, c, n, g, s in _REQS]
    out_cold = cold.run(jax.random.PRNGKey(99))
    assert cold.stats["generated"] == 0
    for a, b in zip(rids, rids2):
        assert np.array_equal(out_warm[a], out_cold[b])


def test_compacted_engine_stats_split_scheduled_vs_active():
    """The honest accounting fix: one-shot ragged reports the frozen
    riding in scheduled-vs-active; full compaction closes the gap to the
    true sum of per-row steps."""
    subs = [(_enc(50), 0, 4, 7.5, 3), (_enc(51), 1, 4, 1.5, 1)]
    true_sum = sum(n * s for _, _, n, _, s in subs)
    rag = _engine(ragged=True)
    for e, c, n, g, s in subs:
        rag.submit(e, c, n, guidance=g, num_steps=s)
    rag.run(jax.random.PRNGKey(6))
    assert rag.stats["row_iters_active"] == true_sum
    assert rag.stats["row_iters_scheduled"] == 8 * 3   # wave rows x smax
    cmp_ = _engine(compaction="full")
    for e, c, n, g, s in subs:
        cmp_.submit(e, c, n, guidance=g, num_steps=s)
    cmp_.run(jax.random.PRNGKey(6))
    assert (cmp_.stats["row_iters_scheduled"]
            == cmp_.stats["row_iters_active"] == true_sum)
    assert cmp_.stats["segments"] == 2
    # grouped mode: no freezing, but alignment padding is still device
    # work — active counts only the real rows' own steps, so every mode
    # agrees on the workload's useful work
    grp = _engine()
    for e, c, n, g, s in subs:
        grp.submit(e, c, n, guidance=g, num_steps=s)
    grp.run(jax.random.PRNGKey(6))
    assert grp.stats["row_iters_active"] == true_sum
    assert (grp.stats["row_iters_scheduled"] - true_sum
            == 4 * 3 + 4 * 1)                   # padded rows x group steps


def test_segment_shape_bucket_cache_reused_across_drains():
    """The second drain of an identical workload re-plans against the
    shape-bucket cache: same geometries, no new compiled shapes."""
    eng = _engine(compaction="auto", compaction_compile_cost=0)
    for e, c, n, g, s in _REQS:
        eng.submit(e, c, n, guidance=g, num_steps=s)
    eng.run(jax.random.PRNGKey(7))
    geoms = set(eng._segment_geoms)
    shapes = eng.stats["compiled_shapes"]
    eng2 = _engine(compaction="auto", compaction_compile_cost=0)
    for e, c, n, g, s in _REQS:
        eng2.submit(e, c, n, guidance=g, num_steps=s)
    eng2.run(jax.random.PRNGKey(8))
    assert eng2._segment_geoms == geoms
    assert eng2.stats["compiled_shapes"] == shapes


def test_compaction_knob_validation_and_threading():
    eng = _engine()
    assert eng.compaction is None and not eng.ragged
    eng.set_compaction("full")
    assert eng.compaction == "full" and eng.ragged       # implies ragged
    eng.set_compaction(None)
    assert eng.compaction == "full"                      # None = leave alone
    eng.set_compaction("off")
    assert eng.compaction is None
    with pytest.raises(ValueError, match="compaction"):
        eng.set_compaction(0)
    with pytest.raises(ValueError, match="compaction"):
        _engine(compaction="fastest")
    svc_eng = _engine()
    SynthesisService(svc_eng, compaction=3)
    assert svc_eng.compaction == 3 and svc_eng.ragged


def test_run_paths_thread_compaction():
    from repro.core.oscar import synthesize
    params, sched = _dm()
    enc = np.stack([np.stack([_enc(60 + c) for c in range(3)])])
    present = np.ones((1, 3), bool)
    eng = _engine()
    sx, _ = synthesize(jax.random.PRNGKey(0), params, DC, sched, enc,
                       present, 2, image_size=H, engine=eng,
                       compaction="full")
    assert eng.compaction == "full" and eng.ragged
    assert eng.stats["segments"] > 0
    assert sx.shape == (6, H, H, 3)
    # opt-in only: a later caller passing "off" must not force the shared
    # engine's compaction back (disable directly via set_compaction)
    synthesize(jax.random.PRNGKey(1), params, DC, sched, enc, present, 2,
               image_size=H, engine=eng, compaction="off")
    assert eng.compaction == "full"
