"""Sharding-rule coverage: every parameter of every assigned arch gets a
spec whose sharded dims divide evenly on the production mesh (checked
shape-only — no devices needed)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import init_lm
from repro.sharding.rules import MeshAxes, param_specs
from repro.utils import tree_paths

AX = MeshAxes(data=("data",), model="model")
MESH_SHAPE = {"data": 16, "model": 16, "pod": 2}


def _shards_for(entry):
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    return int(np.prod([MESH_SHAPE[n] for n in names]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    specs = param_specs(sds, AX)
    flat_s = dict(tree_paths(sds))
    flat_p = dict(tree_paths(specs))
    assert set(flat_s) == set(flat_p)
    vol_sharded = vol_total = 0.0
    for path, spec in flat_p.items():
        shape = flat_s[path].shape
        assert isinstance(spec, P)
        assert len(spec) <= len(shape), (path, spec, shape)
        k_total = 1
        for dim, entry in zip(shape, spec):
            k = _shards_for(entry)
            assert dim % k == 0, f"{arch}:{path} dim {dim} not /{k} ({spec})"
            k_total *= k
        vol = float(np.prod(shape))
        vol_total += vol
        if k_total > 1:
            vol_sharded += vol
    # the big weights must actually be sharded, not silently replicated
    assert vol_sharded / vol_total > 0.95


@pytest.mark.parametrize("arch", ["qwen3-32b", "jamba-1.5-large-398b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_big_arch_fits_per_device_budget(arch):
    """Params+Adam under the (16,16) mesh must fit in 16 GB/chip HBM."""
    cfg = get_config(arch)
    sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    specs = param_specs(sds, AX)
    flat_s = dict(tree_paths(sds))
    flat_p = dict(tree_paths(specs))
    per_dev = 0.0
    for path, s in flat_s.items():
        k = int(np.prod([_shards_for(e) for e in flat_p[path]]))
        per_dev += np.prod(s.shape) * 4 / k      # f32 master
    total = per_dev * 3                           # + mu + nu
    n_dev = 256 if arch != "jamba-1.5-large-398b" else 512
    scale = 1 if arch != "jamba-1.5-large-398b" else 2  # 2-pod data axis
    assert total / scale < 16e9, f"{arch}: {total/scale/1e9:.1f} GB/dev"


def test_embed_is_vocab_parallel():
    cfg = get_config("qwen2-7b")
    sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    specs = dict(tree_paths(param_specs(sds, AX)))
    assert specs["embed/embedding"][0] == "model"


def test_norm_scales_replicated():
    cfg = get_config("qwen3-32b")
    sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    for path, spec in tree_paths(param_specs(sds, AX)):
        if path.endswith("norm1/scale") or path.endswith("final_norm/scale"):
            assert all(e is None for e in spec) or len(spec) == 0


# ---------------------------------------------------------------------------
# mesh construction: fail fast with actionable errors off-TPU
# ---------------------------------------------------------------------------

def test_production_mesh_refuses_undersized_device_set():
    """Off-TPU the production shapes must refuse up front with a message
    naming the shortfall and the local alternatives — not crash deep
    inside jax.make_mesh."""
    from repro.launch.mesh import make_production_mesh, make_serving_mesh
    if jax.device_count() >= 256:          # pragma: no cover - TPU pod only
        pytest.skip("enough devices for the production mesh")
    with pytest.raises(ValueError, match="devices.*make_host_mesh"):
        make_production_mesh()
    with pytest.raises(ValueError, match="devices"):
        make_production_mesh(multi_pod=True)
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh(hosts=2, data=256, model=16)


def test_serving_mesh_and_host_submesh():
    from repro.launch.mesh import (host_submesh, make_host_mesh,
                                   make_serving_mesh, mesh_axes)
    mesh = make_serving_mesh(hosts=1, data=jax.device_count(), model=1)
    assert mesh.axis_names == ("hosts", "data", "model")
    # the hosts axis is placement, never a sharding axis
    ax = mesh_axes(mesh)
    assert ax.data == ("data",) and ax.model == "model"
    sub = host_submesh(mesh, 0)
    assert sub.axis_names == ("data", "model")
    assert sub.devices.size == mesh.devices.size      # 1 host owns all
    with pytest.raises(ValueError, match="out of range"):
        host_submesh(mesh, 1)
    with pytest.raises(ValueError, match="hosts"):
        host_submesh(make_host_mesh(1, 1), 0)
    with pytest.raises(ValueError, match=">= 1"):
        make_serving_mesh(hosts=0)


def test_wave_window_specs_shard_rows_replicate_scalar_table():
    """The row-window sharding rule: a host window's image rows shard
    over the host data axes; the wave-resident scalar table and the
    wave-wide guidance vector replicate (the kernel's row_offset
    indexing replaces per-host resharding)."""
    from repro.sharding.rules import wave_window_specs
    specs = wave_window_specs(AX)
    assert specs["window"] == P("data", None, None, None)
    assert specs["cond"] == P("data", None)
    assert specs["row_keys"] == P("data")
    assert all(e is None for e in specs["scalar_table"])
    assert all(e is None for e in specs["guidance"])
    # multi-axis data meshes fold every data axis into the batch dim
    multi = wave_window_specs(MeshAxes(data=("pod", "data"), model="model"))
    assert multi["window"][0] == ("pod", "data")
