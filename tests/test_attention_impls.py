"""Attention implementation parity: the chunked (flash-semantics) XLA path
and the Pallas kernel must match the naive reference through the full
model, across the zoo's attention variants."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_config
from repro.models.attention import _attend, _attend_chunked, make_mask
from repro.models.moe import Parallel
from repro.models.transformer import forward, init_lm


@pytest.mark.parametrize("arch", ["qwen2-7b", "gemma2-2b", "olmoe-1b-7b",
                                  "hubert-xlarge"])
def test_chunked_equals_naive_full_model(rng_key, arch):
    cfg = smoke_config(get_config(arch))
    params = init_lm(rng_key, cfg)
    if cfg.frontend == "audio_frames":
        batch = {"frames": jax.random.normal(rng_key, (2, 32, cfg.frontend_dim)),
                 "mask": jax.random.bernoulli(rng_key, 0.3, (2, 32)),
                 "labels": jax.random.randint(rng_key, (2, 32), 0,
                                              cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(rng_key, (2, 64), 0,
                                              cfg.vocab_size)}
    a, _ = forward(params, cfg, batch, Parallel(attn_impl="naive"),
                   mode="train")
    b, _ = forward(params, cfg, batch, Parallel(attn_impl="chunked"),
                   mode="train")
    assert jnp.max(jnp.abs(a - b)) < 5e-5


@pytest.mark.parametrize("blk", [8, 16, 64])
def test_chunked_block_size_invariance(rng_key, blk):
    cfg = smoke_config(get_config("qwen2-7b"))
    ks = jax.random.split(rng_key, 3)
    B, S, hd = 2, 64, cfg.head_dim
    q = jax.random.normal(ks[0], (B, S, cfg.num_heads, hd))
    k = jax.random.normal(ks[1], (B, S, cfg.num_kv_heads, hd))
    v = jax.random.normal(ks[2], (B, S, cfg.num_kv_heads, hd))
    ref = _attend(q, k, v, make_mask(S, S, causal=True, window=0), cfg, 0)
    out = _attend_chunked(q, k, v, cfg, causal=True, window=0, blk=blk)
    assert jnp.max(jnp.abs(out - ref)) < 5e-5


def test_serve1d_specs_drop_data_axis(rng_key):
    from repro.sharding.rules import MeshAxes, param_specs
    from repro.utils import tree_paths
    cfg = get_config("qwen3-32b")
    sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    train = dict(tree_paths(param_specs(sds, MeshAxes(("data",), "model"))))
    serve = dict(tree_paths(param_specs(sds, MeshAxes(("data",), "model"),
                                        mode="serve1d")))
    def axes(spec):
        out = set()
        for e in spec:
            if e is None:
                continue
            out |= set(e) if isinstance(e, tuple) else {e}
        return out
    for path in train:
        assert "data" not in axes(serve[path]), path
    # model-axis sharding preserved on the big weights
    assert "model" in axes(serve["groups/p0/mixer/wq/w"])
