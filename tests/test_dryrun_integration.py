"""Integration test: the multi-pod dry-run pipeline end to end, as a
subprocess (it must own the 512-device XLA flag before jax init)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.parametrize("arch,shape", [("xlstm-125m", "decode_32k")])
def test_dryrun_subprocess_single_pair(tmp_path, arch, shape):
    out = tmp_path / "dr.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", str(out)],
        cwd=ROOT, capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    data = json.loads(out.read_text())
    (key, res), = data.items()
    assert res["status"] == "ok", res
    assert res["n_devices"] == 256
    assert res["flops_per_device"] > 0
    assert res["bytes_per_device"] > 0
    assert res["roofline"]["t_compute"] > 0
    assert res["bottleneck"] in ("compute", "memory", "collective")
    # decode of an SSM arch: KV-free recurrent state, tiny compute
    assert res["roofline"]["t_compute"] < 1e-3


def test_train_launcher_smoke_in_process(monkeypatch, capsys):
    """The production training launcher end to end on a 1x1 host mesh:
    builds the mesh, shards the train state per the partition rules, and
    steps the jitted train step (in-process — unlike the dry-run it has
    no device-count requirement, so the tier-1 coverage gate sees it)."""
    from repro.launch import train as launch_train
    monkeypatch.setattr(sys, "argv", [
        "train", "--arch", "xlstm-125m", "--smoke", "--steps", "2",
        "--batch", "2", "--seq", "16"])
    launch_train.main()
    out = capsys.readouterr().out
    assert "step    0 loss" in out
    assert "2 steps in" in out


def test_dryrun_records_documented_skip(tmp_path):
    out = tmp_path / "dr.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "hubert-xlarge", "--shape", "decode_32k", "--out", str(out)],
        cwd=ROOT, capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    (key, res), = json.loads(out.read_text()).items()
    assert res["status"] == "skip"
    assert "encoder-only" in res["note"]
