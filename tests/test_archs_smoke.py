"""Per-assigned-architecture smoke tests (task spec): a REDUCED variant of
the same family (≤2 groups, d_model ≤ 512, ≤4 experts) runs one forward +
one train step on CPU; output shapes and finiteness asserted.  Decoders
additionally run a prefill→decode consistency check against the full
forward pass."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.attention import KVCache
from repro.models.moe import Parallel
from repro.models.transformer import (decode_step, forward, init_caches,
                                      init_lm, loss_fn)
from repro.train.steps import init_train_state, make_train_step


def _batch_for(cfg, key, B=2, S=32):
    if cfg.frontend == "token":
        return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_patches":
        P = cfg.num_prefix_tokens
        return {"patches": jax.random.normal(key, (B, P, cfg.frontend_dim)),
                "tokens": jax.random.randint(key, (B, S - P), 0, cfg.vocab_size)}
    return {"frames": jax.random.normal(key, (B, S, cfg.frontend_dim)),
            "mask": jax.random.bernoulli(key, 0.3, (B, S)),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(rng_key, arch):
    cfg = smoke_config(get_config(arch))
    assert cfg.d_model <= 512 and cfg.num_groups <= 2
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_lm(rng_key, cfg)
    batch = _batch_for(cfg, rng_key)
    logits, aux = forward(params, cfg, batch, mode="train")
    B = 2
    S_total = 32
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits)))

    state = init_train_state(rng_key, cfg)
    step = jax.jit(make_train_step(cfg))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state2.params)[0]
    assert not jnp.allclose(d0, d1)


def _pad_kv(caches, max_len):
    def pad_leaf(c):
        if isinstance(c, KVCache):
            pad = max_len - c.k.shape[2]
            k = jnp.pad(c.k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(c.v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            return KVCache(k, v)
        return c
    return {k: pad_leaf(v) for k, v in caches.items()}


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "hubert-xlarge"])
def test_prefill_decode_matches_forward(rng_key, arch):
    cfg = smoke_config(get_config(arch))
    if cfg.frontend == "vision_patches":
        pytest.skip("vlm decode covered by decode-only smoke")
    params = init_lm(rng_key, cfg)
    B, S, K = 2, 16, 3
    toks = jax.random.randint(rng_key, (B, S + K), 0, cfg.vocab_size)
    logits_full, _ = forward(params, cfg, {"tokens": toks}, mode="train")
    lp, _, caches = forward(params, cfg, {"tokens": toks[:, :S]}, mode="prefill")
    caches = _pad_kv(caches, S + K)
    errs = [float(jnp.max(jnp.abs(lp[:, -1] - logits_full[:, S - 1])))]
    for i in range(K):
        lg, caches = decode_step(params, cfg, toks[:, S + i:S + i + 1],
                                 caches, jnp.int32(S + i))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, S + i]))))
    assert max(errs) < 5e-4, errs


def test_encoder_has_no_decode():
    cfg = get_config("hubert-xlarge")
    assert cfg.is_encoder and not cfg.supports_decode


def test_decode_only_smoke_vlm(rng_key):
    cfg = smoke_config(get_config("internvl2-1b"))
    params = init_lm(rng_key, cfg)
    caches = init_caches(cfg, 2, 24)
    logits, caches2 = decode_step(params, cfg, jnp.zeros((2, 1), jnp.int32),
                                  caches, jnp.int32(5))
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
