"""Mamba / xLSTM block invariants: the chunkwise-parallel forward must
equal running the O(1) recurrent decode step token by token."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_config
from repro.models import ssm, xlstm


def _cfg(arch):
    return smoke_config(get_config(arch))


def test_mamba_chunked_matches_stepwise(rng_key):
    cfg = _cfg("jamba-1.5-large-398b")
    p = ssm.init_mamba(rng_key, cfg)
    B, S = 2, 24
    x = jax.random.normal(rng_key, (B, S, cfg.d_model)) * 0.5
    full = ssm.mamba_forward(p, cfg, x, chunk=8)
    state = ssm.init_mamba_state(cfg, B, x.dtype)
    outs = []
    for t in range(S):
        o, state = ssm.mamba_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(full - step)) < 1e-4


def test_mamba_chunk_size_invariance(rng_key):
    cfg = _cfg("jamba-1.5-large-398b")
    p = ssm.init_mamba(rng_key, cfg)
    x = jax.random.normal(rng_key, (2, 32, cfg.d_model)) * 0.5
    a = ssm.mamba_forward(p, cfg, x, chunk=8)
    b = ssm.mamba_forward(p, cfg, x, chunk=32)
    assert jnp.max(jnp.abs(a - b)) < 1e-4


def test_mamba_state_continuation(rng_key):
    """prefill(S) state + decode == forward(S+1)."""
    cfg = _cfg("jamba-1.5-large-398b")
    p = ssm.init_mamba(rng_key, cfg)
    x = jax.random.normal(rng_key, (1, 17, cfg.d_model)) * 0.5
    full = ssm.mamba_forward(p, cfg, x[:, :17], chunk=17)
    out, state = ssm.mamba_forward(p, cfg, x[:, :16], chunk=16,
                                   return_state=True)
    o_last, _ = ssm.mamba_decode(p, cfg, x[:, 16:17], state)
    assert jnp.max(jnp.abs(o_last - full[:, 16:17])) < 1e-4


def test_mlstm_chunked_matches_stepwise(rng_key):
    cfg = _cfg("xlstm-125m")
    p = xlstm.init_mlstm(rng_key, cfg)
    B, S = 2, 24
    x = jax.random.normal(rng_key, (B, S, cfg.d_model)) * 0.5
    full = xlstm.mlstm_forward(p, cfg, x, chunk=8)
    state = xlstm.init_mlstm_state(cfg, B, x.dtype)
    outs = []
    for t in range(S):
        o, state = xlstm.mlstm_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(full - step)) < 2e-4


def test_mlstm_chunk_size_invariance(rng_key):
    cfg = _cfg("xlstm-125m")
    p = xlstm.init_mlstm(rng_key, cfg)
    x = jax.random.normal(rng_key, (2, 32, cfg.d_model)) * 0.5
    a = xlstm.mlstm_forward(p, cfg, x, chunk=4)
    b = xlstm.mlstm_forward(p, cfg, x, chunk=32)
    assert jnp.max(jnp.abs(a - b)) < 2e-4


def test_slstm_forward_matches_stepwise(rng_key):
    cfg = _cfg("xlstm-125m")
    p = xlstm.init_slstm(rng_key, cfg)
    B, S = 2, 16
    x = jax.random.normal(rng_key, (B, S, cfg.d_model)) * 0.5
    full = xlstm.slstm_forward(p, cfg, x)
    state = xlstm.init_slstm_state(cfg, B, x.dtype)
    outs = []
    for t in range(S):
        o, state = xlstm.slstm_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(full - step)) < 2e-4


def test_mlstm_stabiliser_long_range(rng_key):
    """Exponential gates must not overflow over long sequences."""
    cfg = _cfg("xlstm-125m")
    p = xlstm.init_mlstm(rng_key, cfg)
    x = jax.random.normal(rng_key, (1, 256, cfg.d_model)) * 3.0
    out = xlstm.mlstm_forward(p, cfg, x, chunk=32)
    assert bool(jnp.all(jnp.isfinite(out)))
