"""Ragged waves: per-row (guidance, steps) vectorization of the reverse
core and the engine/service layers above it.

The load-bearing property throughout is PACKING INDEPENDENCE: a row's
output depends only on its own (encoding, guidance, steps, noise key) —
never on the wave's other rows, the step ceiling, alignment padding, or
whether the row arrived up front or streamed in mid-drain.  That is what
lets one compiled wave geometry serve every classifier-free group at
once without changing a single pixel of any row.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.guidance import ragged_tables, respaced_ts
from repro.diffusion.sampler import sample_cfg_ragged
from repro.diffusion.schedule import make_schedule
from repro.serve import SynthesisEngine, SynthesisService

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8


@pytest.fixture(scope="module")
def dm():
    key = jax.random.PRNGKey(0)
    params = init_dit(key, DC, H, 3)
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
    params = jax.tree.unflatten(treedef, [
        a + 0.05 * jax.random.normal(k, a.shape, a.dtype)
        for a, k in zip(leaves, keys)])
    sched = make_schedule(DC.train_timesteps, DC.schedule)
    return params, sched


def _engine(dm, **kw):
    params, sched = dm
    kw.setdefault("image_size", H)
    kw.setdefault("wave_size", 8)
    kw.setdefault("ragged", True)
    return SynthesisEngine(params, DC, sched, **kw)


def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


def _row_keys(base, n):
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(n, dtype=jnp.uint32))


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------

def test_ragged_tables_right_aligned(dm):
    _, sched = dm
    steps = np.array([6, 3, 1], np.int32)
    ts, ab_t, ab_prev, jloc = ragged_tables(sched, steps, 6)
    assert ts.shape == ab_t.shape == ab_prev.shape == jloc.shape == (3, 6)
    alpha_bar = np.asarray(sched.alpha_bar)
    for b, k in enumerate(steps):
        own = np.asarray(respaced_ts(sched.T, int(k)))
        assert np.array_equal(ts[b, 6 - k:], own)      # verbatim slice
        assert np.array_equal(jloc[b], np.arange(6) - (6 - k))
        assert np.array_equal(ab_t[b, 6 - k:], alpha_bar[own])
        assert ab_prev[b, -1] == 1.0                    # terminal ᾱ_prev
        # frozen slots carry valid schedule values (finite masked lanes)
        assert np.all(np.isfinite(ab_t[b])) and np.all(ab_t[b] > 0)


def test_ragged_tables_reject_undersized_ceiling(dm):
    _, sched = dm
    with pytest.raises(ValueError, match="max_steps"):
        ragged_tables(sched, np.array([4, 6]), 5)


@given(seed=st.integers(0, 12), extra=st.integers(0, 4))
@settings(max_examples=25, deadline=None)
def test_ragged_tables_invariants_fuzzed(seed, extra):
    """Property: for ANY per-row step vector and ceiling, every row's
    table slice is its own strictly-decreasing ``respaced_ts`` verbatim,
    right-aligned, with the frozen prefix holding the first real value —
    the per-row contract ragged AND compacted scheduling both consume."""
    from repro.diffusion.schedule import make_schedule
    rng = np.random.default_rng(seed)
    T = int(rng.integers(4, 33))
    sched = make_schedule(T, "cosine")
    B = int(rng.integers(1, 8))
    steps = rng.integers(1, T + 1, B).astype(np.int32)
    S = int(steps.max()) + extra
    ts, ab_t, ab_prev, jloc = ragged_tables(sched, steps, S)
    alpha_bar = np.asarray(sched.alpha_bar)
    for b, k in enumerate(steps):
        own = np.asarray(respaced_ts(T, int(k)))
        assert bool(np.all(np.diff(own) <= -1)) if k > 1 else True
        assert np.array_equal(ts[b, S - k:], own)        # verbatim slice
        assert np.array_equal(jloc[b], np.arange(S) - (S - k))
        assert np.array_equal(ab_t[b, S - k:], alpha_bar[own])
        assert ab_prev[b, -1] == 1.0
        # frozen prefix repeats the first real slot (finite masked lanes)
        assert bool(np.all(ts[b, :S - k] == own[0]))
        assert bool(np.all(np.isfinite(ab_t[b])) and np.all(ab_t[b] > 0))


@given(k=st.integers(1, 16), B=st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_ragged_tables_agreeing_rows_match_uniform_fuzzed(k, B, dm):
    """Property: when every row agrees on its step count (and the ceiling
    is tight) the ragged tables ARE the uniform trajectory broadcast over
    rows — grouped and ragged waves see identical schedules."""
    _, sched = dm
    k = min(k, sched.T)
    steps = np.full((B,), k, np.int32)
    ts, ab_t, ab_prev, jloc = ragged_tables(sched, steps, k)
    own = np.asarray(respaced_ts(sched.T, k))
    ab = np.asarray(sched.alpha_bar)[own]
    abp = np.concatenate([ab[1:], np.ones((1,), np.float32)])
    assert np.array_equal(ts, np.broadcast_to(own, (B, k)))
    assert np.array_equal(ab_t, np.broadcast_to(ab, (B, k)))
    assert np.array_equal(ab_prev, np.broadcast_to(abp, (B, k)))
    assert bool(np.all(jloc >= 0))                 # no frozen iterations


@given(extra=st.integers(1, 8))
@settings(max_examples=10, deadline=None)
def test_ragged_tables_reject_oversubscribed_rows_fuzzed(extra, dm):
    """Property: a row demanding more steps than the ceiling (ultimately
    more than T distinct timesteps) refuses at any scale."""
    _, sched = dm
    with pytest.raises(ValueError, match="max_steps"):
        ragged_tables(sched, np.array([2, 2 + extra]), 2)
    with pytest.raises(ValueError, match="cannot"):
        respaced_ts(sched.T, sched.T + extra)


# ---------------------------------------------------------------------------
# sampler core: per-row bit-exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_ragged_rows_bit_exact_vs_isolated_groups(dm, use_pallas):
    """Each (guidance, steps) group inside one mixed ragged wave must be
    bit-exact against the same rows sampled alone (same row keys) — the
    parity that justifies merging groups into shared waves."""
    params, sched = dm
    B = 6
    y = jax.random.normal(jax.random.PRNGKey(1), (B, DC.cond_dim))
    rk = _row_keys(jax.random.PRNGKey(7), B)
    g = jnp.array([7.5, 7.5, 1.5, 1.5, 4.0, 4.0], jnp.float32)
    steps = np.array([3, 3, 2, 2, 3, 2], np.int32)
    mixed = sample_cfg_ragged(params, DC, sched, y, rk, g, steps,
                              image_size=H, use_pallas=use_pallas)
    assert float(jnp.abs(mixed).max()) <= 1.0
    for idx in ([0, 1], [2, 3], [4], [5]):
        i = np.array(idx)
        iso = sample_cfg_ragged(params, DC, sched, y[i], rk[i], g[i],
                                steps[i], image_size=H,
                                use_pallas=use_pallas)
        assert np.array_equal(np.asarray(mixed[i]), np.asarray(iso))


def test_ragged_rows_independent_of_step_ceiling(dm):
    """Raising max_steps only lengthens the frozen prefix — outputs are
    bit-identical, which is what lets the engine reuse one compiled
    geometry as deeper rows arrive."""
    params, sched = dm
    y = jax.random.normal(jax.random.PRNGKey(2), (3, DC.cond_dim))
    rk = _row_keys(jax.random.PRNGKey(8), 3)
    g = jnp.full((3,), 7.5)
    steps = np.array([2, 2, 2], np.int32)
    a = sample_cfg_ragged(params, DC, sched, y, rk, g, steps, image_size=H)
    b = sample_cfg_ragged(params, DC, sched, y, rk, g, steps, max_steps=5,
                          image_size=H)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_ragged_rows_independent_of_padding_rows(dm):
    """Extra rows in the wave (packer padding duplicates a real row) never
    perturb the real rows."""
    params, sched = dm
    y = jax.random.normal(jax.random.PRNGKey(3), (2, DC.cond_dim))
    rk = _row_keys(jax.random.PRNGKey(9), 2)
    g = jnp.array([7.5, 1.5])
    steps = np.array([3, 2], np.int32)
    bare = sample_cfg_ragged(params, DC, sched, y, rk, g, steps,
                             image_size=H)
    y_pad = jnp.concatenate([y, y[-1:], y[-1:]])
    rk_pad = jnp.concatenate([rk, rk[-1:], rk[-1:]])
    padded = sample_cfg_ragged(params, DC, sched, y_pad, rk_pad,
                               jnp.concatenate([g, g[-1:], g[-1:]]),
                               np.array([3, 2, 2, 2], np.int32),
                               image_size=H)
    assert np.array_equal(np.asarray(bare), np.asarray(padded[:2]))
    # and the duplicated rows really are copies of the row they clone
    assert np.array_equal(np.asarray(padded[1]), np.asarray(padded[2]))


# ---------------------------------------------------------------------------
# engine: merged waves
# ---------------------------------------------------------------------------

def test_ragged_engine_merges_cfg_groups(dm):
    """Three (guidance, steps) groups share waves: fewer waves, fewer
    padded rows, ONE compiled geometry (vs one per group when grouped)."""
    subs = [(_enc(0), 0, 3, 1.5, 3), (_enc(1), 1, 3, 7.5, 3),
            (_enc(2), 2, 3, 7.5, 2)]
    grp = _engine(dm, ragged=False)
    for e, c, n, g, s in subs:
        grp.submit(e, c, n, guidance=g, num_steps=s)
    grp.run(jax.random.PRNGKey(5))
    rag = _engine(dm)
    rids = [rag.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in subs]
    out = rag.run(jax.random.PRNGKey(5))
    for rid, (e, c, n, g, s) in zip(rids, subs):
        assert out[rid].shape == (n, H, H, 3)
        assert np.abs(out[rid]).max() <= 1.0
    assert rag.stats["padded"] < grp.stats["padded"]
    assert rag.stats["waves"] < grp.stats["waves"]
    assert rag.stats["compiled_shapes"] == 1
    assert grp.stats["compiled_shapes"] == len(subs)
    assert rag.stats["merged_waves"] == rag.stats["waves"]


def test_ragged_engine_packing_independent_across_drains(dm):
    """A mixed single drain and per-group isolated drains (same run key,
    same rids) produce bit-identical rows — wave packing is invisible."""
    key = jax.random.PRNGKey(9)
    mixed = _engine(dm)
    r0 = mixed.submit(_enc(10), 0, 4, guidance=1.5, num_steps=3)
    r1 = mixed.submit(_enc(11), 1, 4, guidance=7.5, num_steps=2)
    out = mixed.run(key)

    solo0 = _engine(dm)
    s0 = solo0.submit(_enc(10), 0, 4, guidance=1.5, num_steps=3)
    out0 = solo0.run(key)
    solo1 = _engine(dm)
    solo1._next_rid = 1                      # align the row identity
    s1 = solo1.submit(_enc(11), 1, 4, guidance=7.5, num_steps=2)
    out1 = solo1.run(key)
    assert np.array_equal(out[r0], out0[s0])
    assert np.array_equal(out[r1], out1[s1])


def test_ragged_merges_every_guidance_mode(dm):
    """Ragged merging covers EVERY guidance mode: cfg, classifier-guided
    (per-row ε̂-correction with a batched classifier ensemble) and uncond
    (s=0 null-cond) requests share ONE merged wave — no legacy grouped
    clf/uncond waves are dispatched — and each request's rows are
    bit-identical to the same engine serving its mode alone."""
    key = jax.random.PRNGKey(6)
    eng = _engine(dm, cache=False)
    lp = lambda x, labels: -jnp.sum(x ** 2, axis=(1, 2, 3))
    rc = eng.submit(_enc(20), 0, 3, guidance=7.5, num_steps=3)
    rl = eng.submit_classifier_guided(lp, 1, 3, group="client0",
                                      num_steps=3)
    ru = eng.submit_unconditional(2)
    out = eng.run(key)
    assert out[rc].shape == out[rl].shape == (3, H, H, 3)
    assert out[ru].shape == (2, H, H, 3)
    assert eng.stats["merged_waves"] == 1          # ONE wave for all modes
    assert eng.stats["waves"] == 1
    # no legacy grouped clf/uncond executables were compiled
    assert all(s[0].startswith(("cfg", "mixed"))
               for s in eng.traj_shapes), eng.traj_shapes
    # per-mode isolated oracles (rid-aligned) are bit-identical
    for rid, sub in [
            (rc, lambda e: e.submit(_enc(20), 0, 3, guidance=7.5,
                                    num_steps=3)),
            (rl, lambda e: e.submit_classifier_guided(
                lp, 1, 3, group="client0", num_steps=3)),
            (ru, lambda e: e.submit_unconditional(2))]:
        solo = _engine(dm, cache=False)
        solo._next_rid = rid                     # align the row identity
        srid = sub(solo)
        assert np.array_equal(out[rid], solo.run(key)[srid])


def test_ragged_cache_topup_and_2d_encodings(dm):
    """(encoding-hash, guidance, steps) caching is unchanged in ragged
    mode: exact resubmission hits, larger counts top up with a cached
    prefix, and FedDISC-style 2-D requests stay single cache entries."""
    eng = _engine(dm)
    enc = _enc(30)
    ra = eng.submit(enc, 0, 4, guidance=7.5)
    first = eng.run(jax.random.PRNGKey(3))[ra]
    waves = eng.stats["waves"]
    rb = eng.submit(enc, 0, 4, guidance=7.5)
    again = eng.run(jax.random.PRNGKey(99))[rb]
    assert np.array_equal(first, again)
    assert eng.stats["waves"] == waves             # pure cache hit
    rc = eng.submit(enc, 0, 7, guidance=7.5)
    more = eng.run(jax.random.PRNGKey(4))[rc]
    assert more.shape[0] == 7 and np.array_equal(more[:4], first)
    mat = np.stack([_enc(40 + i) for i in range(4)])
    rd = eng.submit(mat, 0, guidance=1.5, num_steps=2)
    out = eng.run(jax.random.PRNGKey(5))[rd]
    assert out.shape == (4, H, H, 3)
    re_ = eng.submit(mat, 0, guidance=1.5, num_steps=2)
    assert np.array_equal(eng.run(jax.random.PRNGKey(6))[re_], out)


# ---------------------------------------------------------------------------
# service: streaming drains
# ---------------------------------------------------------------------------

def _svc(dm, **kw):
    eng = _engine(dm, ragged=kw.pop("ragged", True))
    return SynthesisService(eng, **kw)


def test_service_mixed_streaming_drain_matches_snapshot_trace(dm):
    """Acceptance: a mixed-group STREAMING drain (late arrivals fused into
    open ragged waves) returns results bit-identical to the same arrival
    trace served across two snapshot drains — packing, streaming, and
    padding are all invisible to row values."""
    key = jax.random.PRNGKey(11)
    initial = [(_enc(50), 0, 3, 1.5, 3), (_enc(51), 1, 2, 7.5, 2)]
    late = [(_enc(52), 2, 2, 7.5, 3), (_enc(53), 0, 1, 1.5, 2)]

    snap = _svc(dm)
    fs = [snap.submit(e, c, n, guidance=g, num_steps=s)
          for e, c, n, g, s in initial]
    snap.drain(key)
    fs += [snap.submit(e, c, n, guidance=g, num_steps=s)
           for e, c, n, g, s in late]
    snap.drain(key)                         # same run key: same identities

    strm = _svc(dm)
    ft = [strm.submit(e, c, n, guidance=g, num_steps=s)
          for e, c, n, g, s in initial]
    trace = list(late)

    def poll():
        if not trace:
            return False
        e, c, n, g, s = trace.pop(0)
        ft.append(strm.submit(e, c, n, guidance=g, num_steps=s))
        return True

    strm.drain(key, poll=poll)
    assert strm.stats["streamed"] == 2
    assert strm.stats["drains"] == 1
    for a, b in zip(fs, ft):
        assert np.array_equal(a.result(), b.result())
    # and the fused drain generated no more rows than the split one
    assert strm.stats["generated"] <= snap.stats["generated"]


def test_service_ragged_flag_threads_to_engine(dm):
    params, sched = dm
    eng = SynthesisEngine(params, DC, sched, image_size=H)
    assert not eng.ragged
    SynthesisService(eng, ragged=True)
    assert eng.ragged
    # opt-in only: constructing without the flag leaves the mode alone
    SynthesisService(eng)
    assert eng.ragged


def test_run_paths_thread_ragged_flag(dm):
    from repro.core.oscar import synthesize
    params, sched = dm
    enc = np.stack([np.stack([_enc(60 + c) for c in range(3)])])
    present = np.ones((1, 3), bool)
    eng = _engine(dm, ragged=False)
    sx, sy = synthesize(jax.random.PRNGKey(0), params, DC, sched, enc,
                        present, 2, image_size=H, engine=eng, ragged=True)
    assert eng.ragged and eng.stats["merged_waves"] > 0
    assert sx.shape == (6, H, H, 3)
