"""SynthesisService behaviour: futures, streaming admission into open
waves, the deterministic drain-key stream, and the persistent
content-addressed D_syn store (cold-process warm-store reruns)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.schedule import make_schedule
from repro.serve import (RequestFailedError, SynthesisEngine,
                         SynthesisFuture, SynthesisService, SynthesisStore)

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8


@pytest.fixture(scope="module")
def dm():
    key = jax.random.PRNGKey(0)
    params = init_dit(key, DC, H, 3)
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
    params = jax.tree.unflatten(treedef, [
        a + 0.05 * jax.random.normal(k, a.shape, a.dtype)
        for a, k in zip(leaves, keys)])
    sched = make_schedule(DC.train_timesteps, DC.schedule)
    return params, sched


def _service(dm, **kw):
    params, sched = dm
    eng = SynthesisEngine(params, DC, sched, image_size=H, wave_size=8,
                          async_waves=kw.pop("async_waves", True))
    return SynthesisService(eng, **kw)


def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


def test_submit_returns_pending_future_result_drains(dm):
    svc = _service(dm, key=0)
    fut = svc.submit(_enc(0), 0, 3)
    assert isinstance(fut, SynthesisFuture) and not fut.done()
    out = fut.result()                      # triggers the drain
    assert fut.done()
    assert out.shape == (3, H, H, 3)


def test_gather_preserves_submission_order(dm):
    svc = _service(dm, key=0)
    futs = [svc.submit(_enc(i), i % 3, c) for i, c in enumerate((2, 5, 3))]
    outs = svc.gather(futs)
    assert [o.shape[0] for o in outs] == [2, 5, 3]
    assert svc.stats["drains"] == 1         # one drain served all three


def test_drain_key_stream_is_deterministic(dm):
    outs = []
    for _ in range(2):
        svc = _service(dm, key=7)
        f = svc.submit(_enc(1), 0, 4)
        g = svc.submit(_enc(2), 1, 4)
        svc.drain()
        h = svc.submit(_enc(3), 2, 4)       # second drain, next stream key
        svc.drain()
        outs.append([f.result(), g.result(), h.result()])
    for a, b in zip(*outs):
        assert np.array_equal(a, b)


def test_streaming_fills_open_waves_fewer_padding(dm):
    """Acceptance: on the same arrival trace, the streaming drain packs
    late arrivals into open waves and pads fewer rows than snapshot
    drains."""
    initial = [(_enc(10), 0, 3), (_enc(11), 1, 2)]        # 5 rows
    late = [(_enc(20), 2, 2), (_enc(21), 0, 1)]           # 3 more, mid-drain

    # snapshot path: late arrivals form a second drain
    snap = _service(dm, key=3)
    for e, c, n in initial:
        snap.submit(e, c, n)
    snap.drain()
    for e, c, n in late:
        snap.submit(e, c, n)
    snap.drain()

    # streaming path: poll feeds the same arrivals into the open drain
    strm = _service(dm, key=3)
    for e, c, n in initial:
        strm.submit(e, c, n)
    trace = list(late)

    def poll():
        if not trace:
            return False
        strm.submit(*trace.pop(0))
        return True

    out = strm.drain(poll=poll)
    assert len(out) == 4
    assert strm.stats["streamed"] == 2
    # 5+3 rows fill ONE 8-row wave; the snapshot path pads each of its
    # two drains up to a full wave
    assert strm.stats["padded"] == 0
    assert strm.stats["padded"] < snap.stats["padded"]
    # both paths generate the same REAL rows; streaming schedules fewer
    assert strm.stats["generated"] == snap.stats["generated"]
    assert strm.stats["scheduled_rows"] < snap.stats["scheduled_rows"]


def test_warm_store_cold_process_zero_sampler_calls(dm, tmp_path):
    """Acceptance: a cold process (fresh engine + fresh store handle on
    the same directory) serves a repeated workload with zero sampler
    calls and bit-identical D_syn."""
    store_dir = tmp_path / "dsyn"
    warm = _service(dm, key=5, store=SynthesisStore(store_dir))
    futs = [warm.submit(_enc(30 + i), i, 5) for i in range(3)]
    outs = warm.gather(futs)
    assert warm.stats["generated"] > 0

    cold = _service(dm, key=5, store=SynthesisStore(store_dir))
    futs2 = [cold.submit(_enc(30 + i), i, 5) for i in range(3)]
    outs2 = cold.gather(futs2)
    assert cold.stats["generated"] == 0
    assert cold.stats["waves"] == 0
    assert cold.stats["store_hits"] > 0
    for a, b in zip(outs, outs2):
        assert np.array_equal(a, b)


def test_store_topup_after_restore(dm, tmp_path):
    """A larger count against a warm store generates only the top-up."""
    store_dir = tmp_path / "dsyn"
    warm = _service(dm, key=6, store=SynthesisStore(store_dir))
    warm.submit(_enc(50), 0, 4).result()

    cold = _service(dm, key=6, store=SynthesisStore(store_dir))
    out = cold.submit(_enc(50), 0, 6).result()
    assert out.shape[0] == 6
    assert cold.stats["cache_hits"] == 4            # restored prefix
    assert cold.stats["generated"] == 2             # just the top-up rows
    assert cold.stats["scheduled_rows"] == 8        # one granule top-up wave

    # and the store now holds the union for the NEXT process
    cold2 = _service(dm, key=6, store=SynthesisStore(store_dir))
    out2 = cold2.submit(_enc(50), 0, 6).result()
    assert cold2.stats["generated"] == 0
    assert np.array_equal(out, out2)


def test_store_layout_and_validation(dm, tmp_path):
    store_dir = tmp_path / "dsyn"
    svc = _service(dm, key=8, store=SynthesisStore(store_dir))
    svc.submit(_enc(60), 0, 2).result()

    manifest = json.loads((store_dir / "manifest.json").read_text())
    assert manifest["version"] == 1
    (slug, ent), = manifest["entries"].items()
    assert ent["count"] == 2 and ent["dtype"] == "float32"
    assert ent["shape"] == [2, H, H, 3]
    assert (store_dir / ent["file"]).exists()
    assert ent["file"] == f"shards/{slug}.npz"

    key = (ent["key"]["encoding_sha1"], ent["key"]["guidance"],
           ent["key"]["steps"])

    # a shard SHORTER than its entry (lost same-key flush race) is a
    # miss — re-synthesize rather than crash every future process
    ent["count"] = 99
    ent["shape"][0] = 99
    (store_dir / "manifest.json").write_text(json.dumps(manifest))
    assert SynthesisStore(store_dir).get(key) is None

    # structural corruption (wrong row shape) must never be served — the
    # shard is QUARANTINED (entry healed, file moved aside) and the key
    # misses so the engine regenerates it
    ent["count"] = 2
    ent["shape"] = [2, H + 1, H, 3]
    (store_dir / "manifest.json").write_text(json.dumps(manifest))
    s2 = SynthesisStore(store_dir)
    assert s2.get(key) is None
    assert s2.metrics.get("store.quarantined") == 1
    assert key not in s2
    assert (store_dir / "quarantine" / f"{slug}.npz").exists()
    assert not (store_dir / ent["file"]).exists()
    healed = json.loads((store_dir / "manifest.json").read_text())
    assert slug not in healed["entries"]

    # a slug recording a different key than requested is manifest
    # corruption — same containment, caught before the shard is read
    ent["shape"] = [2, H, H, 3]
    ent["key"]["steps"] = 999
    (store_dir / "manifest.json").write_text(json.dumps(manifest))
    s3 = SynthesisStore(store_dir)
    assert s3.get(key) is None
    assert s3.metrics.get("store.quarantined") == 1


def test_midwave_submit_streams_into_drain_without_poll(dm):
    """A request submitted while a wave is in flight (the cross-thread
    path, simulated from inside the sampler) joins the SAME drain at the
    next wave boundary — no poll callback required."""
    svc = _service(dm, key=18)
    svc.submit(_enc(99), 0, 8)                   # one full wave
    eng = svc.engine
    orig = eng._sample_wave
    injected = []

    def inject(head, rows, key):
        if not injected:
            injected.append(svc.submit(_enc(100), 1, 8))
        return orig(head, rows, key)

    eng._sample_wave = inject
    out = svc.drain()                            # no poll
    eng._sample_wave = orig
    fut, = injected
    assert fut.rid in out and fut.done()
    assert fut.result().shape == (8, H, H, 3)
    assert svc.stats["streamed"] == 1


def test_sync_and_async_waves_bit_identical(dm):
    """The double-buffered dispatch is a scheduling change only — results
    must match the fenced synchronous path exactly."""
    outs = []
    for async_waves in (False, True):
        svc = _service(dm, key=9, async_waves=async_waves)
        futs = [svc.submit(_enc(70 + i), i, c)
                for i, c in enumerate((3, 9, 5))]
        outs.append(svc.gather(futs))
    for a, b in zip(*outs):
        assert np.array_equal(a, b)


def test_partial_drain_failure_resolves_served_futures(dm):
    """Failure isolation at the service: a permanent sampler failure in
    one wave group resolves ONLY that group's futures to typed errors —
    the drain returns normally, other tenants keep their results, and a
    resubmit after the fault is fixed serves fresh rows."""
    svc = _service(dm, key=13)
    fa = svc.submit(_enc(90), 0, 4, guidance=1.0)
    fb = svc.submit(_enc(91), 1, 4, guidance=9.0)   # later-sorted group
    eng = svc.engine
    orig = eng._sample_wave
    calls = []

    def failing(head, rows, key):
        calls.append(1)
        if len(calls) > 1:
            raise RuntimeError("sampler died mid-drain")
        return orig(head, rows, key)

    eng._sample_wave = failing
    out = svc.drain()                       # one tenant poisoned: no raise
    assert fa.done() and fa.result().shape == (4, H, H, 3)
    assert fa.rid in out and fb.rid not in out
    err = fb.exception()
    assert isinstance(err, RequestFailedError) and err.rid == fb.rid
    assert "mid-drain" in str(err.__cause__)
    with pytest.raises(RequestFailedError):
        fb.result()
    assert eng.metrics.get("requests_failed") == 1
    eng._sample_wave = orig
    retry = svc.submit(_enc(91), 1, 4, guidance=9.0)
    assert retry.result().shape == (4, H, H, 3)     # healed resubmit


def test_store_serves_manifest_prefix_of_outrun_shard(dm, tmp_path):
    """Crash tolerance: a shard holding MORE rows than its manifest entry
    (crash between shard and manifest renames) serves the recorded
    prefix instead of refusing."""
    store_dir = tmp_path / "dsyn"
    svc = _service(dm, key=14, store=SynthesisStore(store_dir))
    first = svc.submit(_enc(95), 0, 4).result()
    svc.submit(_enc(95), 0, 6).result()             # shard grows to 6 rows

    manifest = json.loads((store_dir / "manifest.json").read_text())
    (slug, ent), = manifest["entries"].items()
    ent["count"] = 4                                # roll the manifest back
    ent["shape"][0] = 4
    (store_dir / "manifest.json").write_text(json.dumps(manifest))
    cold = SynthesisStore(store_dir)
    rows = cold.get((ent["key"]["encoding_sha1"], ent["key"]["guidance"],
                     ent["key"]["steps"]))
    assert rows.shape[0] == 4
    assert np.array_equal(rows, first)


def test_streamed_repeat_after_finalize_tops_up(dm):
    """Regression: a same-cache-key request streamed in AFTER its earlier
    twin finalized must see those rows as cached (not still 'planned') —
    double-counting left it an unservable waiter and dropped it from the
    drain."""
    svc = _service(dm, key=15)
    fa = svc.submit(_enc(96), 0, 4, guidance=1.0)
    svc.submit(_enc(97), 1, 8, guidance=9.0)   # keeps the drain open
    repeat = []

    def poll():
        if repeat:
            return False
        if fa.done():                # group 1 finalized; drain still live
            repeat.append(svc.submit(_enc(96), 0, 8, guidance=1.0))
        return True

    out = svc.drain(poll=poll)
    fr, = repeat
    assert fr.rid in out and fr.done()
    r = fr.result()
    assert r.shape[0] == 8
    assert np.array_equal(r[:4], fa.result())   # cached prefix + top-up


def test_second_service_on_same_engine_does_not_orphan_futures(dm):
    """Regression: wrapping a shared engine in a throwaway service (the
    synthesize(engine=...) back-compat path) must not steal result
    delivery from the longer-lived service's futures."""
    svc_a = _service(dm, key=16)
    SynthesisService(svc_a.engine, key=17)      # e.g. a throwaway wrapper
    fut = svc_a.submit(_enc(98), 0, 3)
    assert fut.result().shape == (3, H, H, 3)


def _fill_store(dm, store_dir, seeds, *, key=21, count=2):
    svc = _service(dm, key=key, store=SynthesisStore(store_dir))
    outs = {s: svc.submit(_enc(s), 0, count).result() for s in seeds}
    return svc, outs


def test_store_evict_lru_under_budget(dm, tmp_path):
    """evict(max_bytes) drops least-recently-used shards first, is a
    no-op under budget, and never corrupts the manifest: a cold handle
    validates and serves every surviving entry."""
    store_dir = tmp_path / "dsyn"
    svc, outs = _fill_store(dm, store_dir, [100, 101, 102, 103])
    store = svc.store
    per = 2 * H * H * 3 * 4                     # bytes per 2-row f32 shard
    assert store.total_bytes() == 4 * per
    assert store.evict(10 ** 9) == []           # under budget: no-op
    # touch the oldest entry THROUGH THE STORE so recency, not insertion,
    # decides (an engine-cache hit never reaches the store's LRU)
    assert store.get(_key_for(dm, 100)) is not None
    evicted = store.evict(2 * per)
    assert len(evicted) == 2 and store.total_bytes() <= 2 * per

    cold = SynthesisStore(store_dir)
    assert len(cold) == 2
    hits = 0
    for s in (100, 101, 102, 103):
        rows = cold.get(_key_for(dm, s))
        if rows is not None:
            hits += 1
            assert np.array_equal(rows, outs[s])
    assert hits == 2
    assert cold.get(_key_for(dm, 100)) is not None   # the touched survivor
    # evicted shard files are gone, survivors intact
    assert len(list((store_dir / "shards").glob("*.npz"))) == 2


def _key_for(dm, seed, *, count=2):
    from repro.serve.synthesis import _encoding_hash
    return (_encoding_hash(_enc(seed)), DC.guidance_scale,
            DC.sample_timesteps)


def test_store_evicted_key_resynthesizes_and_heals(dm, tmp_path):
    """An evicted key is a clean miss: the next request regenerates it and
    the store heals — no error, no wrong rows."""
    store_dir = tmp_path / "dsyn"
    svc, outs = _fill_store(dm, store_dir, [110, 111])
    svc.store.evict(0)                          # evict everything
    assert len(SynthesisStore(store_dir)) == 0
    cold = _service(dm, key=21, store=SynthesisStore(store_dir))
    again = cold.submit(_enc(110), 0, 2).result()
    assert cold.stats["generated"] > 0          # regenerated, not served
    assert again.shape == outs[110].shape
    assert len(SynthesisStore(store_dir)) == 1  # healed on flush


def test_store_eviction_tombstones_survive_flush(dm, tmp_path):
    """A flush after evict must not resurrect evicted entries from the
    on-disk manifest merge (the tombstone path)."""
    store_dir = tmp_path / "dsyn"
    svc, _ = _fill_store(dm, store_dir, [120, 121])
    store = svc.store
    victims = store.evict(0)
    assert len(victims) == 2
    # new work dirties the store; its flush merges against disk
    svc.submit(_enc(122), 0, 2).result()
    cold = SynthesisStore(store_dir)
    assert len(cold) == 1
    assert cold.get(_key_for(dm, 122)) is not None


def test_store_get_missing_shard_file_is_miss(dm, tmp_path):
    """A shard file deleted out from under a live handle (another process
    evicting a shared root) is a MISS, not a crash — re-synthesize."""
    store_dir = tmp_path / "dsyn"
    svc, _ = _fill_store(dm, store_dir, [140])
    (store_dir / "shards" / f"{next(iter(svc.store._manifest['entries']))}"
     ".npz").unlink()
    assert SynthesisStore(store_dir).get(_key_for(dm, 140)) is None


def test_store_evict_with_dirty_entries_keeps_manifest_consistent(tmp_path):
    """evict() while puts are still buffered must not publish a manifest
    entry whose shard is not on disk — every surviving entry a cold
    handle sees must load."""
    store_dir = tmp_path / "dsyn"
    rows = np.zeros((2, 4, 4, 3), np.float32)
    st = SynthesisStore(store_dir)
    ka, kb, kc = [(f"{i:040x}", 7.5, 3) for i in range(3)]
    st.put(ka, rows)
    st.put(kb, rows)
    st.flush()
    st.put(kc, rows + 1.0)                  # dirty, unflushed
    st.evict(2 * rows.nbytes + 1)           # room for two entries
    cold = SynthesisStore(store_dir)
    served = 0
    for key in (ka, kb, kc):
        got = cold.get(key)
        if got is not None:
            served += 1
    assert served == len(cold) == 2
    assert cold.get(kc) is not None          # the freshest entry survived


def test_store_evict_crash_between_manifest_and_unlink(dm, tmp_path,
                                                       monkeypatch):
    """Crash ordering: evict() rewrites the manifest BEFORE unlinking
    shard files.  Simulate dying in that window — every unlink fails
    after the manifest rename landed — and reopen cold: the store must
    never reference a missing shard (victims left the manifest first)
    and never lose a live entry (survivors load bit-exactly); the only
    residue is orphaned shard files."""
    from pathlib import Path
    store_dir = tmp_path / "dsyn"
    svc, outs = _fill_store(dm, store_dir, [150, 151, 152, 153])
    store = svc.store
    per = 2 * H * H * 3 * 4
    live = {s: dict(e) for s, e in store._manifest["entries"].items()}

    real_unlink = Path.unlink

    def dying_unlink(self, *a, **kw):
        if self.suffix == ".npz":
            raise RuntimeError("crashed between manifest write and unlink")
        return real_unlink(self, *a, **kw)

    monkeypatch.setattr(Path, "unlink", dying_unlink)
    with pytest.raises(RuntimeError, match="crashed"):
        store.evict(2 * per)
    monkeypatch.undo()

    cold = SynthesisStore(store_dir)
    assert len(cold) == 2                       # victims left the manifest
    for slug, ent in cold._manifest["entries"].items():
        assert (store_dir / ent["file"]).exists()
        key = (ent["key"]["encoding_sha1"], ent["key"]["guidance"],
               ent["key"]["steps"])
        rows = cold.get(key)                    # every live entry loads
        assert rows is not None and len(rows) == ent["count"]
    # orphaned shard files remain (all 4 on disk) but none is referenced
    # by the manifest — harmless residue, re-synthesis never needed for
    # the survivors
    assert len(list((store_dir / "shards").glob("*.npz"))) == 4
    evicted = set(live) - set(cold._manifest["entries"])
    assert len(evicted) == 2


def test_store_evict_crash_partway_through_unlinks(dm, tmp_path,
                                                   monkeypatch):
    """Dying after SOME victim shards are unlinked is equally safe: the
    manifest already dropped every victim, so a dangling entry can never
    point at a deleted file."""
    from pathlib import Path
    store_dir = tmp_path / "dsyn"
    svc, _ = _fill_store(dm, store_dir, [160, 161, 162, 163])
    store = svc.store

    real_unlink = Path.unlink
    unlinked = []

    def dying_unlink(self, *a, **kw):
        if self.suffix == ".npz":
            if unlinked:
                raise RuntimeError("crashed mid-unlink")
            unlinked.append(self.name)
        return real_unlink(self, *a, **kw)

    monkeypatch.setattr(Path, "unlink", dying_unlink)
    with pytest.raises(RuntimeError, match="mid-unlink"):
        store.evict(0)                          # evict everything
    monkeypatch.undo()

    cold = SynthesisStore(store_dir)
    assert len(cold) == 0                       # manifest emptied first
    # and the store still works: a new put/flush heals around the orphans
    svc2, outs2 = _fill_store(dm, store_dir, [164], key=23)
    cold2 = SynthesisStore(store_dir)
    assert len(cold2) == 1
    assert np.array_equal(cold2.get(_key_for(dm, 164)), outs2[164])


def test_store_evict_crash_before_manifest_write_loses_nothing(dm, tmp_path,
                                                               monkeypatch):
    """Dying BEFORE the manifest rename (while victims were only chosen)
    must leave the store exactly as it was: same entries, every shard
    served."""
    store_dir = tmp_path / "dsyn"
    svc, outs = _fill_store(dm, store_dir, [170, 171, 172])
    store = svc.store

    def dying_write():
        raise RuntimeError("crashed before manifest write")

    monkeypatch.setattr(store, "_write_manifest", dying_write)
    with pytest.raises(RuntimeError, match="before manifest"):
        store.evict(0)
    monkeypatch.undo()

    cold = SynthesisStore(store_dir)
    assert len(cold) == 3
    for s in (170, 171, 172):
        assert np.array_equal(cold.get(_key_for(dm, s)), outs[s])


def test_service_store_budget_evicts_after_drain(dm, tmp_path):
    """store_max_bytes on the service keeps the persistent store under
    budget across drains — a long-lived server stops growing."""
    per = 2 * H * H * 3 * 4
    svc = _service(dm, key=22, store=SynthesisStore(tmp_path / "dsyn"),
                   store_max_bytes=2 * per)
    for i, s in enumerate((130, 131, 132, 133)):
        svc.submit(_enc(s), i % 3, 2).result()
    assert svc.store.total_bytes() <= 2 * per
    assert svc.stats["store_evicted"] >= 2
    assert svc.stats["store_entries"] <= 2
    # the most recent key survived and round-trips from a cold handle
    assert SynthesisStore(tmp_path / "dsyn").get(_key_for(dm, 133)) \
        is not None


def test_oscar_synthesize_routes_through_service(dm):
    from repro.core.oscar import synthesize
    params, sched = dm
    svc = _service(dm, key=11)
    enc = np.stack([np.stack([_enc(80 + c) for c in range(3)])])
    present = np.ones((1, 3), bool)
    sx, sy = synthesize(jax.random.PRNGKey(0), params, DC, sched, enc,
                        present, 2, image_size=H, service=svc)
    assert sx.shape == (6, H, H, 3)
    assert list(sy) == [0, 0, 1, 1, 2, 2]
    assert svc.stats["requests"] == 3
