"""The concurrent placed drain (``workers=True``, the engine default).

The tentpole contract: per-host workers + dispatch-before-fence are a
pure SCHEDULING change.  Row noise is keyed by request identity, so the
concurrent drain is BIT-IDENTICAL to the sequential window loop
(``workers=False``, the oracle here) and to the plain single-host
ragged engine — across H ∈ {2, 4}, every packing mode, random fault
schedules, and FORCED thread interleavings (a barrier in the engine's
``_sync_hook`` test seam holds every host's worker at the same site
before any proceeds).  On top of that:

* per-host admission (``run(host_polls={h: hook})``): every live host's
  hook runs at each wave boundary, a dead host's hook is dropped, and
  the streamed outputs match the snapshot submission bit for bit;
* aborted-wave bookkeeping: a wave killed by ``HostLostError`` burns no
  wave index and freezes no ``pack`` stamp (regression for the
  first-stamp-wins tracer bug);
* overlap is real: at H=2 the two hosts' ``device.scan`` spans overlap
  in wall-clock time.
"""
import threading

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.schedule import make_schedule
from repro.obs import FakeClock, Tracer
from repro.serve import FaultInjector, SynthesisEngine, SynthesisService

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8

_DM = None


def _dm():
    global _DM
    if _DM is None:
        key = jax.random.PRNGKey(0)
        params = init_dit(key, DC, H, 3)
        leaves, treedef = jax.tree.flatten(params)
        keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
        params = jax.tree.unflatten(treedef, [
            a + 0.05 * jax.random.normal(k, a.shape, a.dtype)
            for a, k in zip(leaves, keys)])
        _DM = params, make_schedule(DC.train_timesteps, DC.schedule)
    return _DM


def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


def _engine(**kw):
    params, sched = _dm()
    kw.setdefault("image_size", H)
    kw.setdefault("wave_size", 8)
    kw.setdefault("granule", 1)
    kw.setdefault("cache", False)
    return SynthesisEngine(params, DC, sched, **kw)


def _mixed_requests(seed):
    rng = np.random.default_rng(seed)
    subs = []
    for i in range(int(rng.integers(2, 6))):
        subs.append((_enc(100 * seed + i), int(rng.integers(0, 3)),
                     int(rng.integers(1, 6)),
                     float(rng.choice([1.5, 4.0, 7.5])),
                     int(rng.integers(1, 4))))
    return subs


def _run(subs, key, **kw):
    eng = _engine(**kw)
    rids = [eng.submit(e, c, n, guidance=g, num_steps=s)
            for e, c, n, g, s in subs]
    out = eng.run(key)
    assert sorted(out) == sorted(rids)
    return [out[r] for r in rids], eng


def _schedule_for(seed, hosts):
    rng = np.random.default_rng(1000 + seed)
    sched = []
    for hkill in rng.permutation(hosts)[:int(rng.integers(1, hosts))]:
        sched.append(("window", int(hkill), int(rng.integers(0, 3))))
    for wave in rng.permutation(4)[:int(rng.integers(0, 3))]:
        sched.append(("scan", None, int(wave)))
    return sched


# ---------------------------------------------------------------------------
# bit-identity: workers vs the sequential oracle, fuzzed
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=6)
@given(seed=st.integers(min_value=0, max_value=5),
       hosts=st.sampled_from([2, 4]),
       mode=st.sampled_from(["grouped", "ragged", "compacted"]))
def test_fuzz_concurrent_bit_identical_to_sequential(seed, hosts, mode):
    """workers=True vs workers=False vs the single-host ragged oracle:
    same requests, same key, same fault schedule → bit-identical D_syn
    and zero lost requests, with per-host sums == globals."""
    kw = {"grouped": {}, "ragged": {"ragged": True},
          "compacted": {"compaction": "full"}}[mode]
    subs = _mixed_requests(seed)
    key = jax.random.PRNGKey(seed)
    oracle, _ = _run(subs, key, ragged=True, workers=False)
    schedule = _schedule_for(seed, hosts)
    seq, _ = _run(subs, key, hosts=hosts, workers=False,
                  faults=FaultInjector(schedule=list(schedule)), **kw)
    conc, eng = _run(subs, key, hosts=hosts, workers=True,
                     faults=FaultInjector(schedule=list(schedule)), **kw)
    for a, b, c in zip(oracle, seq, conc):
        assert np.array_equal(a, c)
        assert np.array_equal(b, c)
    s = eng.stats
    assert sum(p["rows"] + p["padded"] for p in s["per_host"]) \
        == s["scheduled_rows"]
    assert sum(p["rows"] for p in s["per_host"]) == s["generated"]
    assert s["scheduled_rows"] == s["generated"] + s["padded"]


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(min_value=0, max_value=3),
       site=st.sampled_from(["dispatch", "fence"]))
def test_fuzz_forced_interleavings_bit_identical(seed, site):
    """The ``_sync_hook`` seam holds EVERY host's worker at one site
    (dispatch or fence) until all arrive — the worst-case interleaving,
    every window in flight simultaneously — and D_syn still matches the
    sequential oracle bit for bit."""
    hosts = 2
    subs = _mixed_requests(seed)
    key = jax.random.PRNGKey(seed)
    seq, _ = _run(subs, key, hosts=hosts, workers=False, ragged=True)

    eng = _engine(hosts=hosts, workers=True, ragged=True)
    barrier = threading.Barrier(hosts, timeout=5.0)

    def hook(s, host, wave):
        if s == site:
            try:
                barrier.wait()
            except threading.BrokenBarrierError:
                pass             # a single-window wave: no partner comes

    eng._sync_hook = hook
    rids = [eng.submit(e, c, n, guidance=g, num_steps=st_)
            for e, c, n, g, st_ in subs]
    out = eng.run(key)
    for r, b in zip(rids, seq):
        assert np.array_equal(out[r], b)


def test_concurrent_matches_sequential_with_service_and_store(tmp_path):
    """A warm store written by the concurrent drain serves a cold
    sequential engine (and vice versa) with zero sampler calls."""
    from repro.serve import SynthesisStore
    subs = [(_enc(70 + i), i % 3, 4, 3.0, 2) for i in range(3)]
    store_dir = tmp_path / "dsyn"
    warm = SynthesisService(_engine(hosts=2, workers=True, ragged=True,
                                    cache=True,
                                    store=SynthesisStore(store_dir)))
    outs = warm.gather([warm.submit(e, c, n, guidance=g, num_steps=s)
                        for e, c, n, g, s in subs], jax.random.PRNGKey(3))
    cold = SynthesisService(_engine(workers=False, ragged=True, cache=True,
                                    store=SynthesisStore(store_dir)))
    outs2 = cold.gather([cold.submit(e, c, n, guidance=g, num_steps=s)
                         for e, c, n, g, s in subs], jax.random.PRNGKey(9))
    assert cold.stats["waves"] == 0 and cold.stats["generated"] == 0
    for a, b in zip(outs, outs2):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# overlap: the concurrency is real, not just correct
# ---------------------------------------------------------------------------

def test_device_scan_spans_overlap_at_two_hosts():
    """At H=2 the hosts' ``device.scan`` spans overlap in wall-clock
    time — the dispatch-before-fence pipeline actually runs windows
    concurrently.  A barrier at the fence site makes the overlap
    deterministic: both spans are open before either fence proceeds."""
    tracer = Tracer()                       # real perf_counter clock
    eng = _engine(hosts=2, workers=True, ragged=True, tracer=tracer)
    barrier = threading.Barrier(2, timeout=5.0)

    def hook(site, host, wave):
        if site == "fence":
            try:
                barrier.wait()
            except threading.BrokenBarrierError:
                pass
    eng._sync_hook = hook
    for i in range(4):
        eng.submit(_enc(40 + i), i % 3, 4, guidance=3.0, num_steps=3)
    eng.run(jax.random.PRNGKey(7))
    scans = [s for s in tracer.spans if s.name == "device.scan"]
    by_host = {h: [s for s in scans if s.attrs.get("host") == h]
               for h in (0, 1)}
    assert by_host[0] and by_host[1]
    overlaps = any(a.start < b.end and b.start < a.end
                   for a in by_host[0] for b in by_host[1])
    assert overlaps, "host windows fenced serially — no overlap"


def test_sequential_oracle_keeps_no_pool():
    """workers=False (and H=1) never builds a pool — the oracle truly
    is the sequential window loop."""
    eng = _engine(hosts=2, workers=False, ragged=True)
    eng.submit(_enc(1), 0, 4, guidance=3.0, num_steps=2)
    eng.run(jax.random.PRNGKey(0))
    assert eng._pool is None
    one = _engine(hosts=1, workers=True, ragged=True)
    one.submit(_enc(1), 0, 4, guidance=3.0, num_steps=2)
    one.run(jax.random.PRNGKey(0))
    assert one._pool is None


# ---------------------------------------------------------------------------
# aborted-wave bookkeeping (trace regression)
# ---------------------------------------------------------------------------

def test_aborted_wave_burns_no_index_and_no_pack_stamp():
    """A wave killed by ``HostLostError`` must not advance the wave
    counter nor freeze its ``pack`` stamp: the committed pack time is
    the SUCCESSFUL repack's, trace ``wave=`` ids agree with the
    ``waves`` counter, and pack → dispatch intervals exclude failover
    repack latency."""
    clock = FakeClock(tick=1.0)
    tracer = Tracer(clock=clock)
    eng = _engine(hosts=2, ragged=True, tracer=tracer,
                  faults=FaultInjector(schedule=[("window", 0, 0)]))
    rid = eng.submit(_enc(9), 0, 4, guidance=3.0, num_steps=2)
    out = eng.run(jax.random.PRNGKey(5))
    assert out[rid].shape[0] == 4
    # the aborted attempt did not advance the counter: one successful
    # wave → waves == 1, and every traced wave id is < waves
    assert eng.stats["waves"] == 1
    wave_ids = {s.attrs["wave"] for s in tracer.spans
                if s.name in ("window.pack", "window.dispatch")
                and "wave" in s.attrs}
    assert wave_ids == {0}
    # the pack stamp postdates the host-loss marker: it is the repack's
    # time, not the aborted first attempt's (first-stamp-wins would have
    # frozen the earlier one had it been committed)
    lost = [s for s in tracer.spans if s.name == "host.failed"]
    assert len(lost) == 1
    stamps = tracer.lifecycle[rid]
    assert stamps["pack"] > lost[0].start
    assert stamps["pack"] <= stamps["dispatch"]


def test_aborted_wave_not_counted_in_stats():
    """Rows from an aborted wave are not double-counted: generated is
    exactly the real rows requested, once."""
    eng = _engine(hosts=2, ragged=True,
                  faults=FaultInjector(schedule=[("window", 1, 0)]))
    rids = [eng.submit(_enc(60 + i), i % 3, 3, guidance=3.0, num_steps=2)
            for i in range(2)]
    out = eng.run(jax.random.PRNGKey(2))
    assert sum(len(out[r]) for r in rids) == 6
    assert eng.stats["generated"] == 6
    assert eng.stats["scheduled_rows"] == \
        eng.stats["generated"] + eng.stats["padded"]


# ---------------------------------------------------------------------------
# per-host streaming admission
# ---------------------------------------------------------------------------

def test_host_polls_stream_bit_identical_to_snapshot():
    """Per-host arrival traces fed through ``host_polls`` produce the
    same rows as submitting everything up front."""
    subs = [(_enc(80 + i), i % 3, 3, 3.0, 2) for i in range(6)]
    key = jax.random.PRNGKey(11)
    snap, _ = _run(subs, key, hosts=2, ragged=True)

    eng = _engine(hosts=2, ragged=True)
    rids = {}
    # route each request to its home host's trace, as a frontend would
    traces = {0: [], 1: []}
    probe = _engine(hosts=2, ragged=True)   # rid assignment preview
    for i, sub in enumerate(subs):
        traces[probe.topology.assign(i)].append((i, sub))

    def hook_for(h):
        def hook():
            if not traces[h]:
                return False
            i, (e, c, n, g, s) = traces[h].pop(0)
            rids[i] = eng.submit(e, c, n, guidance=g, num_steps=s)
            return True
        return hook

    out = eng.run(key, host_polls={0: hook_for(0), 1: hook_for(1)})
    assert eng.stats["streamed"] > 0
    for i, want in enumerate(snap):
        assert np.array_equal(out[rids[i]], want)


def test_host_polls_keep_drain_alive_without_global_poll():
    """host_polls alone (no global poll) keeps the drain alive while
    any hook still has traffic, and implies streaming mode."""
    eng = _engine(hosts=2, ragged=True)
    trace = [(_enc(95 + i), i % 3, 2, 3.0, 2) for i in range(3)]
    got = []

    def hook():
        if not trace:
            return False
        e, c, n, g, s = trace.pop(0)
        got.append(eng.submit(e, c, n, guidance=g, num_steps=s))
        return True

    out = eng.run(jax.random.PRNGKey(4), host_polls={1: hook})
    assert sorted(out) == sorted(got)
    assert all(out[r].shape[0] == 2 for r in got)


def test_host_polls_dropped_for_dead_host():
    """A failed host's hook is dropped — never called again after the
    loss — while survivors' hooks keep running."""
    eng = _engine(hosts=2, ragged=True,
                  faults=FaultInjector(schedule=[("window", 0, 1)]))
    calls = {0: 0, 1: 0}
    trace = [(_enc(120 + i), i % 3, 2, 3.0, 2) for i in range(4)]

    def hook_for(h):
        def hook():
            calls[h] += 1
            if h in eng.topology.failed:      # must never happen
                raise AssertionError("dead host's hook was called")
            if not trace:
                return False
            e, c, n, g, s = trace.pop(0)
            eng.submit(e, c, n, guidance=g, num_steps=s)
            return True
        return hook

    eng.submit(_enc(119), 0, 3, guidance=3.0, num_steps=2)
    out = eng.run(jax.random.PRNGKey(6),
                  host_polls={0: hook_for(0), 1: hook_for(1)})
    assert eng.topology.failed == {0}
    calls_at_loss = calls[0]
    assert calls[1] > calls_at_loss or not trace  # survivor kept polling
    assert len(out) >= 1


def test_host_polls_validation():
    eng = _engine(ragged=True)              # no topology
    with pytest.raises(ValueError, match="topology"):
        eng.run(jax.random.PRNGKey(0), host_polls={0: lambda: False})
    eng2 = _engine(hosts=2, ragged=True)
    with pytest.raises(ValueError, match="out of range"):
        eng2.run(jax.random.PRNGKey(0), host_polls={7: lambda: False})


def test_service_forwards_host_polls():
    svc = SynthesisService(_engine(hosts=2, ragged=True, cache=True))
    fed = []

    def hook():
        if fed:
            return False
        fed.append(svc.submit(_enc(130), 0, 3, guidance=3.0, num_steps=2))
        return True

    svc.drain(jax.random.PRNGKey(1), host_polls={0: hook})
    assert fed and fed[0].done()
    assert fed[0].result().shape[0] == 3
