"""The roofline extractor must be trip-count aware and match hand counts
on known programs (this is what the whole §Roofline rests on)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as hlo


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    text = _compile(f, sds, sds)
    cost = hlo.analyze(text, 1)
    expected = 8 * 2 * 256 ** 3
    assert abs(cost.flops - expected) / expected < 0.02


def test_grad_flops_counted():
    def g(x, w1, w2):
        return jnp.mean(jax.nn.relu(x @ w1) @ w2)

    B, d, h = 128, 512, 1024
    text = _compile(jax.grad(g, argnums=(1, 2)),
                    jax.ShapeDtypeStruct((B, d), jnp.float32),
                    jax.ShapeDtypeStruct((d, h), jnp.float32),
                    jax.ShapeDtypeStruct((h, d), jnp.float32))
    cost = hlo.analyze(text, 1)
    expected = 4 * 2 * B * d * h     # fwd 2 matmuls + dw1 + dw2
    assert abs(cost.flops - expected) / expected < 0.05


def test_collective_wire_factors():
    line_ar = ('%all-reduce.1 = f32[1024]{0} all-reduce(%x), '
               'replica_groups=[16,16]<=[256]')
    stats_text = "ENTRY %main (p: f32[1024]) -> f32[1024] {\n " + line_ar + "\n}"
    cost = hlo.analyze(stats_text, 256)
    # 2*(n-1)/n * 4096 bytes with n=16
    assert abs(cost.collective_bytes - 2 * 15 / 16 * 4096) < 1


def test_dus_counts_slice_not_buffer():
    def f(buf, x):
        return jax.lax.dynamic_update_slice_in_dim(buf, x, 0, axis=0)

    big = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    small = jax.ShapeDtypeStruct((1, 256), jnp.float32)
    cost = hlo.analyze(_compile(f, big, small), 1)
    # traffic should be O(slice + copy of buffer at entry), not O(2 buffers
    # per update); allow the one-time entry copy
    assert cost.bytes < 3 * 4096 * 256 * 4
