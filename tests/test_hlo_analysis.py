"""The roofline extractor must be trip-count aware and match hand counts
on known programs (this is what the whole §Roofline rests on)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as hlo


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    text = _compile(f, sds, sds)
    cost = hlo.analyze(text, 1)
    expected = 8 * 2 * 256 ** 3
    assert abs(cost.flops - expected) / expected < 0.02


def test_grad_flops_counted():
    def g(x, w1, w2):
        return jnp.mean(jax.nn.relu(x @ w1) @ w2)

    B, d, h = 128, 512, 1024
    text = _compile(jax.grad(g, argnums=(1, 2)),
                    jax.ShapeDtypeStruct((B, d), jnp.float32),
                    jax.ShapeDtypeStruct((d, h), jnp.float32),
                    jax.ShapeDtypeStruct((h, d), jnp.float32))
    cost = hlo.analyze(text, 1)
    expected = 4 * 2 * B * d * h     # fwd 2 matmuls + dw1 + dw2
    assert abs(cost.flops - expected) / expected < 0.05


def test_collective_wire_factors():
    line_ar = ('%all-reduce.1 = f32[1024]{0} all-reduce(%x), '
               'replica_groups=[16,16]<=[256]')
    stats_text = "ENTRY %main (p: f32[1024]) -> f32[1024] {\n " + line_ar + "\n}"
    cost = hlo.analyze(stats_text, 256)
    # 2*(n-1)/n * 4096 bytes with n=16
    assert abs(cost.collective_bytes - 2 * 15 / 16 * 4096) < 1


def test_dus_counts_slice_not_buffer():
    def f(buf, x):
        return jax.lax.dynamic_update_slice_in_dim(buf, x, 0, axis=0)

    big = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    small = jax.ShapeDtypeStruct((1, 256), jnp.float32)
    cost = hlo.analyze(_compile(f, big, small), 1)
    # traffic should be O(slice + copy of buffer at entry), not O(2 buffers
    # per update); allow the one-time entry copy
    assert cost.bytes < 3 * 4096 * 256 * 4


# ---------------------------------------------------------------------------
# structural denoiser cost model (fused vs naive dit_apply)
# ---------------------------------------------------------------------------

def test_denoiser_cost_fusion_saves_bytes_not_flops():
    """Fusion changes WHERE intermediates live, not the arithmetic: equal
    FLOPs, strictly fewer HBM bytes, higher arithmetic intensity."""
    from repro.configs.oscar import DiffusionConfig
    dc = DiffusionConfig()
    naive = hlo.denoiser_cost(dc, 256, 16)
    fused = hlo.denoiser_cost(dc, 256, 16, fused=True)
    assert naive["flops"] == fused["flops"]
    assert fused["bytes"] < naive["bytes"]
    assert fused["intensity"] > naive["intensity"]
    bf16 = hlo.denoiser_cost(dc, 256, 16, fused=True, bf16=True)
    assert bf16["bytes"] < fused["bytes"]
    assert bf16["flops"] == fused["flops"]


def test_denoiser_cost_s2_term_scales_quadratically():
    """The naive-vs-fused byte gap is the materialised (B, h, S, S)
    attention plus the extra LN passes; the S² part must dominate its
    growth when the image (hence S) scales up."""
    from repro.configs.oscar import DiffusionConfig
    dc = DiffusionConfig()

    def gap(img):
        n = hlo.denoiser_cost(dc, 8, img)["bytes"]
        f = hlo.denoiser_cost(dc, 8, img, fused=True)["bytes"]
        return n - f

    # 16px → 64px: n_tok ×16, S² ×~256; the gap must grow far superlinearly
    assert gap(64) > 50 * gap(16)


def test_denoiser_cost_roofline_terms_compose():
    from repro.configs.oscar import DiffusionConfig
    dc = DiffusionConfig()
    c = hlo.denoiser_cost(dc, 256, 224, fused=True)
    t = hlo.roofline_terms(c["flops"], c["bytes"], 0.0)
    assert t["t_compute"] > 0 and t["t_memory"] > 0
    assert hlo.dominant_term(t) in ("compute", "memory")
