"""Data-generator and frozen-encoder invariants (property-based)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # pragma: no cover - CI installs it
    from _hypothesis_fallback import given, settings, st

from repro.configs.oscar import DataConfig
from repro.data.federated import make_federated_data
from repro.encoders.foundation import FrozenFM, category_encodings


@pytest.fixture(scope="module")
def data():
    return make_federated_data(DataConfig(num_categories=5,
                                          train_per_cat_dom=8,
                                          test_per_cat_dom=4))


def test_feature_skew_partition(data):
    """Paper §V-b: every client owns exactly one domain, all categories."""
    R = data.client_images.shape[0]
    for r in range(R):
        assert set(np.unique(data.client_domains[r])) == {r}
        assert set(np.unique(data.client_labels[r])) == set(range(5))


def test_images_in_range(data):
    assert data.client_images.min() >= -1.0
    assert data.client_images.max() <= 1.0
    assert data.client_images.dtype == np.float32


def test_determinism():
    dc = DataConfig(num_categories=3, train_per_cat_dom=4, test_per_cat_dom=2)
    a = make_federated_data(dc)
    b = make_federated_data(dc)
    assert np.array_equal(a.client_images, b.client_images)
    assert np.array_equal(a.test_labels, b.test_labels)


def test_encoder_deterministic_and_normalised(data):
    fm = FrozenFM()
    z1 = np.asarray(fm(data.test_images[:16]))
    z2 = np.asarray(fm(data.test_images[:16]))
    assert np.array_equal(z1, z2)
    assert z1.shape == (16, 512)
    assert np.allclose(np.linalg.norm(z1, axis=-1), 1.0, atol=1e-4)


def test_encoder_category_structure(data):
    """Same-category encodings are closer than cross-category on average —
    the geometric property OSCAR's Eq. 7 mean-pooling relies on."""
    fm = FrozenFM()
    x = data.test_images
    y = data.test_labels
    z = np.asarray(fm(x))
    sims = z @ z.T
    same = sims[y[:, None] == y[None, :]].mean()
    diff = sims[y[:, None] != y[None, :]].mean()
    assert same > diff + 0.05


def test_category_encodings_unit_norm_and_present(data):
    fm = FrozenFM()
    m, present = category_encodings(fm, data.client_images[0],
                                    jnp.asarray(data.client_labels[0]), 5)
    assert bool(jnp.all(present))
    norms = jnp.linalg.norm(m, axis=-1)
    assert bool(jnp.all(jnp.abs(norms - 1.0) < 1e-4))


def test_absent_category_is_zero(data):
    fm = FrozenFM()
    # restrict client 0 to labels != 0
    mask = data.client_labels[0] != 0
    m, present = category_encodings(fm, data.client_images[0][mask],
                                    jnp.asarray(data.client_labels[0][mask]), 5)
    assert not bool(present[0])
    assert float(jnp.linalg.norm(m[0])) == 0.0


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_encoder_norm_invariant_any_input(seed):
    rng = np.random.default_rng(seed)
    fm = FrozenFM()
    x = rng.uniform(-1, 1, size=(3, 16, 16, 3)).astype(np.float32)
    z = np.asarray(fm(x))
    assert np.all(np.isfinite(z))
    assert np.allclose(np.linalg.norm(z, axis=-1), 1.0, atol=1e-4)
