"""SynthesisEngine behaviour: wave packing, caching/top-up, grouping,
mesh-aware placement, and the degenerate all-absent OSCAR round."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.sampler import sample_cfg
from repro.diffusion.schedule import make_schedule
from repro.serve.synthesis import SynthesisEngine

DC = DiffusionConfig(d_model=32, num_layers=1, num_heads=2,
                     sample_timesteps=3, train_timesteps=16)
H = 8


@pytest.fixture(scope="module")
def dm():
    key = jax.random.PRNGKey(0)
    params = init_dit(key, DC, H, 3)
    # adaLN-zero init gates the conditioning pathway off; perturb every
    # leaf so guidance scale actually changes the output distribution
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
    params = jax.tree.unflatten(treedef, [
        a + 0.05 * jax.random.normal(k, a.shape, a.dtype)
        for a, k in zip(leaves, keys)])
    sched = make_schedule(DC.train_timesteps, DC.schedule)
    return params, sched


def _engine(dm, **kw):
    params, sched = dm
    kw.setdefault("image_size", H)
    kw.setdefault("wave_size", 8)
    return SynthesisEngine(params, DC, sched, **kw)


def _enc(seed):
    e = np.random.default_rng(seed).normal(size=(DC.cond_dim,))
    return (e / np.linalg.norm(e)).astype(np.float32)


def test_requests_packed_into_uniform_waves(dm):
    eng = _engine(dm)
    rids = [eng.submit(_enc(i), i % 3, c) for i, c in enumerate((3, 5, 2, 6))]
    out = eng.run(jax.random.PRNGKey(1))
    for rid, c in zip(rids, (3, 5, 2, 6)):
        assert out[rid].shape == (c, H, H, 3)
        assert np.abs(out[rid]).max() <= 1.0
    # 16 rows at wave_size 8 → two uniform 8-row waves, zero padding
    assert eng.stats["waves"] == 2
    assert eng.stats["generated"] == 16
    assert eng.stats["padded"] == 0


def test_tail_wave_padded_to_granule(dm):
    eng = _engine(dm)
    eng.submit(_enc(0), 0, 5)
    eng.run(jax.random.PRNGKey(1))
    assert eng.stats["generated"] == 5 and eng.stats["padded"] == 3
    assert eng.stats["scheduled_rows"] == 8


def test_single_full_wave_matches_direct_sampler(dm):
    """One exactly-full wave is one sample_cfg call with fold_in(key, 0)."""
    params, sched = dm
    eng = _engine(dm)
    enc = _enc(3)
    rid = eng.submit(enc, 1, 8)
    key = jax.random.PRNGKey(2)
    out = eng.run(key)[rid]
    direct = sample_cfg(params, DC, sched,
                        jnp.asarray(np.repeat(enc[None], 8, axis=0)),
                        jax.random.fold_in(key, 0), image_size=H,
                        guidance=DC.guidance_scale)
    assert np.array_equal(out, np.asarray(direct))


def test_cache_hit_and_topup(dm):
    eng = _engine(dm)
    enc = _enc(4)
    rid = eng.submit(enc, 0, 4)
    first = eng.run(jax.random.PRNGKey(3))[rid]
    assert eng.stats["cache_hits"] == 0
    # exact resubmission: served from cache, no new waves
    waves = eng.stats["waves"]
    rid = eng.submit(enc, 0, 4)
    again = eng.run(jax.random.PRNGKey(99))[rid]
    assert np.array_equal(first, again)
    assert eng.stats["cache_hits"] == 4 and eng.stats["waves"] == waves
    # larger count: cached prefix + generated top-up rows only
    rid = eng.submit(enc, 0, 7)
    more = eng.run(jax.random.PRNGKey(5))[rid]
    assert more.shape[0] == 7
    assert np.array_equal(more[:4], first)
    assert eng.stats["cache_hits"] == 8


def test_same_key_requests_in_one_drain_generate_once(dm):
    """Two requests with one cache key in the same drain share rows —
    the union is generated once, not twice."""
    eng = _engine(dm)
    enc = _enc(11)
    ra = eng.submit(enc, 0, 3)
    rb = eng.submit(enc, 0, 5)
    out = eng.run(jax.random.PRNGKey(0))
    assert eng.stats["generated"] == 5          # the union, once
    assert eng.stats["scheduled_rows"] == 8     # union (5) + granule pad
    assert eng.stats["cache_hits"] == 3         # ra's rows shared with rb
    assert np.array_equal(out[ra], out[rb][:3])
    assert out[rb].shape[0] == 5


def test_guidance_and_steps_key_the_cache(dm):
    eng = _engine(dm)
    enc = _enc(5)
    ra = eng.submit(enc, 0, 2, guidance=0.0)
    a = eng.run(jax.random.PRNGKey(6))[ra]
    rb = eng.submit(enc, 0, 2, guidance=4.0)
    b = eng.run(jax.random.PRNGKey(6))[rb]
    assert eng.stats["cache_hits"] == 0        # different guidance → no hit
    assert not np.array_equal(a, b)


def test_classifier_guided_groups_do_not_mix(dm):
    eng = _engine(dm)

    def lp_a(x, labels):
        return -jnp.sum(x ** 2, axis=(1, 2, 3))

    def lp_b(x, labels):
        return -jnp.sum((x - 0.5) ** 2, axis=(1, 2, 3))

    ra = eng.submit_classifier_guided(lp_a, 0, 4, group="client0")
    rb = eng.submit_classifier_guided(lp_b, 1, 4, group="client1")
    out = eng.run(jax.random.PRNGKey(7))
    assert out[ra].shape == out[rb].shape == (4, H, H, 3)
    assert eng.stats["waves"] == 2             # one wave per classifier group
    assert not np.array_equal(out[ra], out[rb])


def test_unconditional_requests(dm):
    eng = _engine(dm)
    rid = eng.submit_unconditional(5)
    out = eng.run(jax.random.PRNGKey(8))
    assert out[rid].shape == (5, H, H, 3)


def test_mesh_aware_wave_batches(dm):
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(jax.device_count(), 1)
    eng = _engine(dm, mesh=mesh)
    rid = eng.submit(_enc(6), 0, 8)
    out = eng.run(jax.random.PRNGKey(9))
    assert out[rid].shape == (8, H, H, 3)
    # wave granule divides the data axis → every wave shards evenly
    dsize = mesh.shape["data"]
    assert eng.granule % dsize == 0 and eng.wave_size % dsize == 0


def test_wave_planner_count_below_granule(dm):
    """A request smaller than one granule still costs one granule-sized
    wave — the floor of the wave quantisation."""
    eng = _engine(dm)
    assert eng._plan_waves(3) == (1, 8)
    eng.submit(_enc(20), 0, 3)
    eng.run(jax.random.PRNGKey(0))
    assert eng.stats["generated"] == 3 and eng.stats["padded"] == 5
    assert eng.stats["scheduled_rows"] == 8


def test_wave_planner_exact_wave_multiples(dm):
    """Counts landing exactly on wave boundaries plan zero padding."""
    eng = _engine(dm)
    assert eng._plan_waves(8) == (1, 8)
    assert eng._plan_waves(16) == (2, 8)
    assert eng._plan_waves(24) == (3, 8)
    eng.submit(_enc(21), 0, 24)
    eng.run(jax.random.PRNGKey(0))
    assert eng.stats["waves"] == 3
    assert eng.stats["generated"] == 24 and eng.stats["padded"] == 0


def test_wave_planner_rounded_granule(dm):
    """A granule that does not divide wave_size (the mesh-rounding case:
    granule is rounded UP to the data-parallel device count) rounds the
    wave size up and keeps every wave a granule multiple."""
    eng = _engine(dm, granule=5, wave_size=8)
    assert eng.wave_size == 10                     # ceil(8/5)*5
    for n in (1, 5, 10, 12, 23):
        nw, rows = eng._plan_waves(n)
        assert rows % eng.granule == 0
        assert nw * rows >= n
        assert nw * rows - n < eng.granule * nw    # < one granule per wave
    eng.submit(_enc(22), 0, 12)
    eng.run(jax.random.PRNGKey(0))
    # 12 rows → 2 near-uniform waves of ceil(6/5)*5 = 10 rows
    assert eng.stats["waves"] == 2
    assert eng.stats["generated"] == 12 and eng.stats["padded"] == 8
    assert eng.stats["scheduled_rows"] == 20


def test_two_dim_encoding_one_request_distinct_rows(dm):
    """A (k, cond_dim) submission is ONE request carrying k distinct
    conditionings (the FedDISC shape) — one cache entry, and the rows
    genuinely differ from repeating any single row."""
    eng = _engine(dm)
    mat = np.stack([_enc(60 + i) for i in range(4)])        # (4, D)
    ra = eng.submit(mat, 0)                                 # count inferred
    rb = eng.submit(mat[0], 0, 4)                           # repeated row
    out = eng.run(jax.random.PRNGKey(12))
    assert out[ra].shape == out[rb].shape == (4, H, H, 3)
    assert not np.array_equal(out[ra], out[rb])
    # resubmission is a single full cache hit
    rc = eng.submit(mat, 0)
    again = eng.run(jax.random.PRNGKey(13))[rc]
    assert np.array_equal(again, out[ra])
    with pytest.raises(ValueError, match="rows; count"):
        eng.submit(mat, 0, 3)
    with pytest.raises(ValueError, match="count is required"):
        eng.submit(mat[0], 0)


def test_cache_topup_deterministic_across_drains(dm):
    """Two engines fed the same submission/drain/key trace produce
    bit-identical topped-up cache contents."""
    outs = []
    for _ in range(2):
        eng = _engine(dm)
        enc = _enc(30)
        ra = eng.submit(enc, 0, 4)
        first = eng.run(jax.random.PRNGKey(1))[ra]
        rb = eng.submit(enc, 0, 7)
        more = eng.run(jax.random.PRNGKey(2))[rb]
        assert np.array_equal(more[:4], first)     # cached prefix reused
        outs.append(more)
    assert np.array_equal(outs[0], outs[1])


def test_drain_failure_keeps_unserved_requests(dm):
    """Regression: an exception mid-drain must not drop unserved requests
    — they stay queued and the next drain serves them."""
    eng = _engine(dm)
    ra = eng.submit(_enc(40), 0, 4, guidance=1.0)
    rb = eng.submit(_enc(41), 1, 4, guidance=9.0)   # later-sorted wave group
    orig = eng._sample_wave
    calls = []

    def failing(head, rows, key):
        calls.append(head.guidance)
        if len(calls) > 1:
            raise RuntimeError("sampler died mid-drain")
        return orig(head, rows, key)

    eng._sample_wave = failing
    with pytest.raises(RuntimeError, match="mid-drain"):
        eng.run(jax.random.PRNGKey(3))
    # the first group's wave completed and was served; the second stayed
    queued = {r.rid for r in eng._queue}
    assert rb in queued and ra not in queued
    eng._sample_wave = orig
    out = eng.run(jax.random.PRNGKey(4))
    assert out[rb].shape == (4, H, H, 3)


def test_drain_failure_first_wave_keeps_everything(dm):
    eng = _engine(dm)
    rids = [eng.submit(_enc(42), 0, 4), eng.submit(_enc(43), 1, 4)]

    def always_fail(head, rows, key):
        raise RuntimeError("boom")

    orig, eng._sample_wave = eng._sample_wave, always_fail
    with pytest.raises(RuntimeError):
        eng.run(jax.random.PRNGKey(5))
    assert {r.rid for r in eng._queue} == set(rids)
    eng._sample_wave = orig
    out = eng.run(jax.random.PRNGKey(5))
    assert set(out) == set(rids)


def test_oscar_synthesize_empty_present(dm):
    from repro.core.oscar import synthesize
    params, sched = dm
    enc = np.zeros((2, 3, DC.cond_dim), np.float32)
    present = np.zeros((2, 3), bool)
    sx, sy = synthesize(jax.random.PRNGKey(0), params, DC, sched, enc,
                        present, 4, image_size=H)
    assert sx.shape == (0, H, H, 3) and sx.dtype == np.float32
    assert sy.shape == (0,) and sy.dtype == np.int32


def test_oscar_synthesize_routes_through_engine(dm):
    from repro.core.oscar import synthesize
    params, sched = dm
    eng = _engine(dm)
    enc = np.stack([np.stack([_enc(10 + c) for c in range(3)])])  # (1,3,D)
    present = np.ones((1, 3), bool)
    sx, sy = synthesize(jax.random.PRNGKey(0), params, DC, sched, enc,
                        present, 2, image_size=H, engine=eng)
    assert sx.shape == (6, H, H, 3)
    assert list(sy) == [0, 0, 1, 1, 2, 2]
    assert eng.stats["requests"] == 3
