"""Minimal stand-in for the hypothesis API used by this suite.

When hypothesis isn't installed, ``@given`` tests still run — each one
sweeps a small deterministic set of examples (strategy endpoints +
midpoint) instead of randomised draws.  Only the strategy surface this
repo's tests use is provided: integers, floats, sampled_from.
"""
from __future__ import annotations

import inspect


class _Strategy:
    def __init__(self, examples):
        self.examples = list(dict.fromkeys(examples))


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy([min_value, (min_value + max_value) // 2, max_value])

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy([min_value, (min_value + max_value) / 2.0, max_value])

    @staticmethod
    def sampled_from(elements):
        return _Strategy(list(elements))


st = _Strategies()


def given(**strategies):
    def deco(fn):
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in strategies]

        def wrapper(**kwargs):
            n = max(len(s.examples) for s in strategies.values())
            for i in range(n):
                vals = {k: s.examples[i % len(s.examples)]
                        for k, s in strategies.items()}
                fn(**kwargs, **vals)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        # hide the strategy-bound params so pytest doesn't treat them as
        # fixtures; the remaining params stay fixture-injectable
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper
    return deco


def settings(**_kwargs):
    return lambda fn: fn
