"""checkpoint/io round-trip fidelity: dtypes (incl. bf16 extension
dtypes npz cannot represent natively), manifest validation, and
back-compat with pre-dtype manifests."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt


def _tree():
    return {
        "w_f32": jnp.arange(6, dtype=jnp.float32).reshape(2, 3) / 7,
        "w_bf16": (jnp.arange(8, dtype=jnp.float32) / 3).astype(jnp.bfloat16),
        "step": jnp.asarray(17, jnp.int32),
        "nested": {"b_f16": jnp.ones((4,), jnp.float16) * 0.5},
    }


def test_dtypes_round_trip_exactly(tmp_path):
    tree = _tree()
    p = tmp_path / "ck"
    ckpt.save_pytree(tree, p, meta={"note": "dtype test"})
    loaded = ckpt.load_pytree(tree, p)
    for (path_a, a), (path_b, b) in zip(jax.tree_util.tree_flatten_with_path(tree)[0],
                                        jax.tree_util.tree_flatten_with_path(loaded)[0]):
        assert a.dtype == b.dtype, path_a
        # bit-exact, not allclose: bf16 must not detour through f32 rounding
        assert np.array_equal(np.asarray(a).reshape(-1).view(np.uint8),
                              np.asarray(b).reshape(-1).view(np.uint8)), path_a


def test_manifest_records_dtypes(tmp_path):
    p = tmp_path / "ck"
    ckpt.save_pytree(_tree(), p)
    manifest = json.loads(p.with_suffix(".json").read_text())
    assert manifest["dtypes"]["w_bf16"] == "bfloat16"
    assert manifest["dtypes"]["w_f32"] == "float32"
    assert set(manifest["dtypes"]) == set(manifest["keys"])


def test_bf16_stored_as_raw_bits_not_pickle(tmp_path):
    """The npz must stay loadable with allow_pickle=False — bf16 leaves
    ride as uint16 bit patterns, not pickled void scalars."""
    p = tmp_path / "ck"
    ckpt.save_pytree(_tree(), p)
    with np.load(p.with_suffix(".npz"), allow_pickle=False) as z:
        assert z["w_bf16"].dtype == np.uint16


def test_load_validates_dtype_against_manifest(tmp_path):
    tree = _tree()
    p = tmp_path / "ck"
    ckpt.save_pytree(tree, p)
    manifest = json.loads(p.with_suffix(".json").read_text())
    manifest["dtypes"]["w_f32"] = "float64"        # corrupt the record
    p.with_suffix(".json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="inconsistent with manifest"):
        ckpt.load_pytree(tree, p)


def test_load_rejects_same_width_native_dtype_corruption(tmp_path):
    """uint16 raw bits must only ever be re-viewed as the recorded
    EXTENSION dtype — a manifest edited to a same-width native dtype
    (float16) must raise, not silently reinterpret the bits."""
    tree = _tree()
    p = tmp_path / "ck"
    ckpt.save_pytree(tree, p)
    manifest = json.loads(p.with_suffix(".json").read_text())
    manifest["dtypes"]["w_bf16"] = "float16"
    p.with_suffix(".json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="inconsistent with manifest"):
        ckpt.load_pytree(tree, p)


def test_legacy_manifest_without_dtypes_still_loads(tmp_path):
    tree = {"w": jnp.ones((3,), jnp.float32)}
    p = tmp_path / "ck"
    ckpt.save_pytree(tree, p)
    manifest = json.loads(p.with_suffix(".json").read_text())
    del manifest["dtypes"]                         # pre-PR-2 checkpoint
    p.with_suffix(".json").write_text(json.dumps(manifest))
    loaded = ckpt.load_pytree(tree, p)
    assert np.array_equal(np.asarray(loaded["w"]), np.ones((3,), np.float32))
