"""Serving example: prefill a prompt then greedy-decode with the KV-cache /
recurrent-state runtime, for any assigned architecture (reduced variant on
CPU).

    PYTHONPATH=src python examples/serve_decode.py --arch jamba-1.5-large-398b
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.models.attention import KVCache
from repro.models.transformer import forward, init_lm
from repro.serve.steps import make_serve_step


def pad_kv(caches, max_len):
    def pad_leaf(c):
        if isinstance(c, KVCache):
            pad = max_len - c.k.shape[2]
            return KVCache(
                jnp.pad(c.k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
                jnp.pad(c.v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))))
        return c
    return {k: pad_leaf(v) for k, v in caches.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step "
                         "(DESIGN.md §5)")
    key = jax.random.PRNGKey(0)
    print(f"[serve] {cfg.name}: {cfg.num_layers}L d={cfg.d_model}")
    params = init_lm(key, cfg)

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    total = args.prompt_len + args.new_tokens
    t0 = time.time()
    logits, _, caches = forward(params, cfg, {"tokens": prompt},
                                mode="prefill")
    caches = pad_kv(caches, total)
    print(f"[serve] prefill {args.prompt_len} tokens in {time.time()-t0:.2f}s")

    serve_step = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        tok, _, caches = serve_step(params, tok, caches,
                                    jnp.int32(args.prompt_len + i))
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] decoded {args.new_tokens} tokens in {dt:.2f}s "
          f"({args.new_tokens/dt:.1f} tok/s/seq, batch {args.batch})")
    print("[serve] sample token ids:", toks[0].tolist())


if __name__ == "__main__":
    main()
