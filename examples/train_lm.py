"""LM pre-training example: a few hundred steps of any assigned arch
(reduced variant) on a synthetic in-memory token stream, via the same
train-step factory the multi-pod launcher lowers.

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --steps 200
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.train.steps import init_train_state, make_train_step


def synthetic_tokens(key, n_seq, seq, vocab):
    """Markov-ish synthetic stream: learnable bigram structure."""
    k1, k2 = jax.random.split(key)
    trans = jax.random.dirichlet(k1, jnp.full((vocab,), 0.3), (vocab,))
    toks = [jax.random.randint(k2, (n_seq, 1), 0, vocab)]
    for t in range(seq - 1):
        kt = jax.random.fold_in(k2, t)
        nxt = jax.random.categorical(kt, jnp.log(trans[toks[-1][:, 0]] + 1e-9))
        toks.append(nxt[:, None])
    return jnp.concatenate(toks, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    print(f"[train] {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")
    state = init_train_state(key, cfg)
    step = jax.jit(make_train_step(cfg, lr=1e-3))

    if cfg.frontend == "token":
        data = synthetic_tokens(key, 256, args.seq, cfg.vocab_size)
        def batch_at(i):
            idx = jax.random.randint(jax.random.fold_in(key, i),
                                     (args.batch,), 0, data.shape[0])
            return {"tokens": data[idx]}
    elif cfg.frontend == "audio_frames":
        def batch_at(i):
            k = jax.random.fold_in(key, i)
            return {"frames": jax.random.normal(k, (args.batch, args.seq,
                                                    cfg.frontend_dim)),
                    "mask": jax.random.bernoulli(k, 0.3, (args.batch, args.seq)),
                    "labels": jax.random.randint(k, (args.batch, args.seq), 0,
                                                 cfg.vocab_size)}
    else:
        P = cfg.num_prefix_tokens
        def batch_at(i):
            k = jax.random.fold_in(key, i)
            return {"patches": jax.random.normal(k, (args.batch, P,
                                                     cfg.frontend_dim)),
                    "tokens": jax.random.randint(k, (args.batch, args.seq - P),
                                                 0, cfg.vocab_size)}

    t0 = time.time()
    first = last = None
    for i in range(args.steps):
        state, metrics = step(state, batch_at(i))
        if i == 0:
            first = float(metrics["loss"])
        if i % 50 == 0 or i == args.steps - 1:
            print(f"  step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f}", flush=True)
        last = float(metrics["loss"])
    print(f"[train] {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first else 'no improvement'})")


if __name__ == "__main__":
    main()
