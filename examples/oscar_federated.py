"""End-to-end driver: the full paper experiment — all seven methods on the
feature-skew federated benchmark, with per-client accuracy and upload
accounting (paper Tables I + IV, Fig. 1).

    PYTHONPATH=src python examples/oscar_federated.py [--preset quick|paper]
                                                      [--methods oscar,fedavg]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.oscar import DataConfig, DiffusionConfig, OscarConfig
from repro.core.experiment import ALL_METHODS, Experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="quick", choices=("quick", "paper"))
    ap.add_argument("--methods", default=",".join(ALL_METHODS))
    args = ap.parse_args()

    if args.preset == "quick":
        ocfg = OscarConfig(
            data=DataConfig(num_categories=5, train_per_cat_dom=10,
                            test_per_cat_dom=5),
            diffusion=DiffusionConfig(pretrain_steps=800, batch_size=64),
            classifier_steps=200)
    else:
        ocfg = OscarConfig()

    exp = Experiment(ocfg)
    results = {}
    for m in args.methods.split(","):
        results[m] = exp.run(m)

    print(f"\n{'method':10s} {'avg acc':>8s} {'upload params':>14s}")
    for m, r in sorted(results.items(), key=lambda kv: -kv[1]["avg"]):
        print(f"{m:10s} {r['avg']*100:7.2f}% {r['upload_params']:>14,}")


if __name__ == "__main__":
    main()
