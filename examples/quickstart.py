"""Quickstart: the OSCAR pipeline end to end, minutes-scale on CPU.

    PYTHONPATH=src python examples/quickstart.py

Builds the federated multi-domain dataset, pre-trains (or loads) the
frozen classifier-free DM, runs one OSCAR round (client encodings →
upload → server CFG synthesis → global model), and prints the Table-I-row
metrics + the upload size against FedAvg.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.oscar import DataConfig, DiffusionConfig, OscarConfig
from repro.core.experiment import Experiment


def main():
    ocfg = OscarConfig(
        data=DataConfig(num_categories=5, train_per_cat_dom=10,
                        test_per_cat_dom=5),
        diffusion=DiffusionConfig(pretrain_steps=800, batch_size=64),
        classifier_steps=200,
    )
    exp = Experiment(ocfg)
    oscar = exp.run("oscar")
    fedavg = exp.run("fedavg", rounds=5)
    print("\n-- quickstart summary --")
    print(f"OSCAR : avg acc {oscar['avg']*100:.2f}% | "
          f"upload {oscar['upload_params']:,} params (ONE round)")
    print(f"FedAvg: avg acc {fedavg['avg']*100:.2f}% | "
          f"upload {fedavg['upload_params']:,} params (5 rounds)")
    red = 1 - oscar["upload_params"] / fedavg["upload_params"]
    print(f"communication reduction: {red*100:.2f}%")


if __name__ == "__main__":
    main()
