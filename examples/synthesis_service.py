"""SynthesisService tour: futures, streaming admission, persistent store,
multi-host topology.

    PYTHONPATH=src python examples/synthesis_service.py

Seconds-scale on CPU (random-init DM — serving cost does not depend on
training).  Four acts:

 1. futures      — submit (client, category) encodings, get
                   SynthesisFutures, drain once, read results;
 2. streaming    — an arrival trace delivers requests mid-drain; the wave
                   packer folds them into the open wave (compare padded
                   rows against draining the same trace as two snapshots);
 3. persistence  — a second service ("cold process") against the same
                   on-disk store serves everything with ZERO sampler
                   calls, bit-identically;
 4. topology     — the same workload drained over 2 SIMULATED HOSTS
                   (``hosts=2``): per-host ingress queues, contiguous
                   per-host wave windows reading one wave-resident scalar
                   table through the segment-offset cfg_fuse path, and a
                   per-host stats breakdown — with D_syn bit-identical to
                   the single-host drain (row noise is keyed by request
                   identity, so placement is invisible).
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs.oscar import DiffusionConfig
from repro.diffusion.dit import init_dit
from repro.diffusion.schedule import make_schedule
from repro.serve import SynthesisEngine, SynthesisService, SynthesisStore

DC = DiffusionConfig(d_model=64, num_layers=2, num_heads=2)
H, STEPS, WAVE = 16, 8, 16


def make_engine():
    key = jax.random.PRNGKey(0)
    params = init_dit(key, DC, H, 3)
    sched = make_schedule(DC.train_timesteps, DC.schedule)
    return SynthesisEngine(params, DC, sched, image_size=H, wave_size=WAVE)


def encodings(n):
    e = np.random.default_rng(0).normal(size=(n, DC.cond_dim))
    return (e / np.linalg.norm(e, axis=-1, keepdims=True)).astype(np.float32)


def main():
    store_dir = Path(tempfile.mkdtemp(prefix="dsyn_store_"))
    enc = encodings(8)

    # -- 1. futures -------------------------------------------------------
    svc = SynthesisService(make_engine(), key=42, store=store_dir)
    futs = [svc.submit(enc[c], c, 6, num_steps=STEPS) for c in range(4)]
    print("submitted:", futs[0], "...")
    imgs = svc.gather(futs)
    print(f"act 1 — futures: {len(imgs)} requests served, "
          f"shapes {imgs[0].shape}, stats {svc.stats}")

    # -- 2. streaming admission ------------------------------------------
    svc2 = SynthesisService(make_engine(), key=42)
    for c in range(4):
        svc2.submit(enc[c], c, 3, num_steps=STEPS)   # 12 rows queued

    trace = [(enc[c], c, 3) for c in range(4, 8)]    # 12 more arrive live

    def poll():
        if not trace:
            return False
        svc2.submit(*trace.pop(0), num_steps=STEPS)
        return True

    svc2.drain(poll=poll)

    # same arrival trace, snapshot-drained: arrivals form a second drain
    snap = SynthesisService(make_engine(), key=42)
    for c in range(4):
        snap.submit(enc[c], c, 3, num_steps=STEPS)
    snap.drain()
    for c in range(4, 8):
        snap.submit(enc[c], c, 3, num_steps=STEPS)
    snap.drain()
    print(f"act 2 — streaming: {svc2.stats['streamed']} requests arrived "
          f"mid-drain and filled open waves — padded rows "
          f"{svc2.stats['padded']} vs {snap.stats['padded']} for snapshot "
          f"drains of the same trace")

    # -- 3. persistent store ---------------------------------------------
    cold = SynthesisService(make_engine(), key=42, store=store_dir)
    futs_cold = [cold.submit(enc[c], c, 6, num_steps=STEPS)
                 for c in range(4)]
    imgs_cold = cold.gather(futs_cold)
    assert cold.stats["generated"] == 0, "warm store should skip sampling"
    assert all(np.array_equal(a, b) for a, b in zip(imgs, imgs_cold))
    print(f"act 3 — store: cold process served {len(imgs_cold)} requests "
          f"from {store_dir.name} with zero sampler calls "
          f"(store_hits={cold.stats['store_hits']}), bit-identical")

    # -- 4. multi-host topology ------------------------------------------
    # one-host oracle (ragged row-keyed waves) vs the same trace placed
    # over two simulated hosts: every request routes to a host ingress
    # queue by identity, each host packs its own wave window, and the
    # output bits cannot tell the difference
    one = SynthesisService(make_engine(), key=7, ragged=True)
    f1 = [one.submit(enc[c], c, 5, num_steps=STEPS) for c in range(6)]
    imgs_one = one.gather(f1)

    duo = SynthesisService(make_engine(), key=7, ragged=True, hosts=2)
    f2 = [duo.submit(enc[c], c, 5, num_steps=STEPS) for c in range(6)]
    imgs_duo = duo.gather(f2)
    assert all(np.array_equal(a, b) for a, b in zip(imgs_one, imgs_duo)), \
        "placement leaked into row values"
    print(f"act 4 — topology: {duo.stats['hosts']} simulated hosts drained "
          f"{duo.stats['generated']} rows, bit-identical to single-host; "
          f"per-host stats:")
    for h, p in enumerate(duo.stats["per_host"]):
        print(f"          host {h}: rows={p['rows']} padded={p['padded']} "
              f"waves={p['waves']} iters={p['row_iters_scheduled']}"
              f"/{p['row_iters_active']} "
              f"queue_depth_at_start={p['queue_depth_at_start']}")


if __name__ == "__main__":
    main()
